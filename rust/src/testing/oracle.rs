//! Differential oracles for the pass pipeline: invariants that any run
//! of the `analyze-structure` pipeline (stages 1–2 of the HLPS flow)
//! must preserve on *any* valid input design, checked against
//! independent reference implementations:
//!
//! * **input-drc** — the precondition: the input design is DRC-clean
//!   (the synthetic generator guarantees this by construction).
//! * **pipeline-runs** — the pipeline must not error on valid input.
//! * **drc-preserved** — DRC-clean in ⇒ DRC-clean out.
//! * **bisimulation** — the multiset of leaf-level channels (nets between
//!   leaf-module ports, resolved through arbitrary hierarchy depth by an
//!   independent reference elaborator, [`leaf_channels`]) is identical
//!   before and after the pipeline: restructuring may move boundaries,
//!   never connectivity.
//! * **index-coherence** — the pipeline's warm
//!   [`DesignIndex`](crate::ir::index::DesignIndex) view of
//!   every grouped module equals an independent string-keyed rebuild
//!   ([`reference_block_graph`], the pre-index `BlockGraph::build`
//!   semantics kept verbatim).
//! * **roundtrip-fixpoint** — serializing the output IR, parsing it back
//!   and serializing again is byte-identical (and value-identical).
//! * **determinism** — running the pipeline twice from the same input
//!   yields byte-identical IR JSON and identical logs.
//!
//! [`check_workers_equivalence`] additionally runs a batch of designs on
//! a 1-worker and an 8-worker [`Pool`] (what `RSIR_WORKERS=1` vs `8`
//! resolve to) and requires byte-identical results.
//!
//! [`check_verilog_roundtrip`] drives the *text* path instead of the IR
//! path: it materializes a plan as Verilog/manifest source text
//! ([`synthetic::materialize_sources`]) and checks three invariants —
//!
//! * **verilog-fixpoint** — printing every parsed module with
//!   [`crate::verilog::printer`] and reparsing yields a structurally
//!   identical AST.
//! * **import-bisimulation** — running the pipeline over the *imported
//!   text* reconstructs exactly the leaf-channel multiset of the
//!   directly-materialized IR.
//! * **export-reimport** — exporting the analyzed design and importing
//!   the export again converges: same leaf-channel multiset, and the
//!   same [`digest_class`] (IR digest quotiented by cosmetic naming).
//!
//! [`check_incremental_reflow`] gates the incremental re-flow engine:
//! the HLPS flow run through a shared
//! [`StageMemo`](crate::coordinator::memo::StageMemo) — cold, after a
//! leaf-timing edit, and again on the original design with the polluted
//! memo — must produce bit-for-bit the same outcome (adjudicated by
//! [`flow_fingerprint`]) as from-scratch runs without any memo.
//!
//! A deliberately broken pass must trip at least one oracle — proven by
//! the mutation smoke tests in `tests/fuzz_pipeline.rs`.

use crate::coordinator::flow::{run_hlps_warm, FlowConfig, FlowReport, FlowWarm};
use crate::coordinator::memo::StageMemo;
use crate::designs::synthetic::{self, DesignPlan};
use crate::device::model::VirtualDevice;
use crate::ir::digest::Fnv;
use crate::ir::core::*;
use crate::ir::graph::{BlockGraph, Endpoint, NetInfo};
use crate::ir::schema::{design_from_json, design_to_json};
use crate::ir::validate;
use crate::passes::{registry, PassContext};
use crate::util::json::{Json, JsonObj};
use crate::util::pool::Pool;
use crate::verilog::ast::VModule;
use crate::verilog::parser::parse_file;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

/// One violated invariant, with a human-readable detail.
#[derive(Debug, Clone)]
pub struct OracleViolation {
    pub invariant: &'static str,
    pub detail: String,
}

impl fmt::Display for OracleViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Result of one oracle run. Empty violations = every invariant held.
#[derive(Debug, Clone, Default)]
pub struct OracleOutcome {
    pub violations: Vec<OracleViolation>,
}

impl OracleOutcome {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Names of the violated invariants, in order.
    pub fn violated(&self) -> Vec<&'static str> {
        self.violations.iter().map(|v| v.invariant).collect()
    }

    pub fn render(&self) -> String {
        if self.is_clean() {
            return "all oracle invariants held".to_string();
        }
        self.violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn push(&mut self, invariant: &'static str, detail: impl Into<String>) {
        self.violations.push(OracleViolation {
            invariant,
            detail: detail.into(),
        });
    }
}

/// The transformation under test by default: the registered
/// `analyze-structure` pipeline, DRC hooks off (matching how
/// `run_baseline`/`run_hlps` invoke it — mid-pipeline states may be
/// transiently inconsistent; the oracles judge the final state).
pub fn analyze_pipeline(design: &mut Design, ctx: &mut PassContext) -> anyhow::Result<()> {
    ctx.drc_after_each = false;
    registry::named(registry::ANALYZE_STRUCTURE)?.run(design, ctx)?;
    Ok(())
}

/// Run the full oracle suite over the default pipeline.
pub fn check_pipeline(design: &Design) -> OracleOutcome {
    check_pipeline_with(design, analyze_pipeline)
}

/// Run the full oracle suite over an arbitrary transformation — the hook
/// the mutation smoke tests use to prove a broken pass is caught. `run`
/// must announce its mutations through `ctx.index` (as any well-formed
/// pass would) or debug builds panic on the stale cache instead of
/// reporting a violation.
pub fn check_pipeline_with<F>(design: &Design, run: F) -> OracleOutcome
where
    F: Fn(&mut Design, &mut PassContext) -> anyhow::Result<()>,
{
    let mut out = OracleOutcome::default();

    let pre = validate::check(design);
    if !pre.is_empty() {
        out.push(
            "input-drc",
            format!("input design violates DRC ({} violations): {}", pre.len(), pre[0]),
        );
        return out; // downstream invariants are meaningless
    }
    let pre_channels = leaf_channels(design);

    let mut d1 = design.clone();
    let mut ctx1 = PassContext::new();
    ctx1.drc_after_each = false;
    if let Err(e) = run(&mut d1, &mut ctx1) {
        out.push("pipeline-runs", format!("pipeline failed on valid input: {e:#}"));
        return out;
    }

    // DRC-clean in ⇒ DRC-clean out.
    let post = validate::check(&d1);
    if !post.is_empty() {
        out.push(
            "drc-preserved",
            format!(
                "{} violations after pipeline; first: {}",
                post.len(),
                post[0]
            ),
        );
    }

    // Connectivity bisimulation at the leaf level.
    let post_channels = leaf_channels(&d1);
    if pre_channels != post_channels {
        out.push(
            "bisimulation",
            channel_diff(&pre_channels, &post_channels),
        );
    }

    // The warm index view must match the reference rebuild.
    for name in d1
        .modules
        .values()
        .filter(|m| m.is_grouped())
        .map(|m| m.name.clone())
        .collect::<Vec<_>>()
    {
        match ctx1.index.conn(&d1, &name) {
            Ok((conn, interner)) => {
                let view = conn.to_block_graph(interner);
                let reference = reference_block_graph(d1.module(&name).unwrap());
                if view != reference {
                    out.push(
                        "index-coherence",
                        format!("indexed view of '{name}' diverges from reference rebuild"),
                    );
                }
            }
            Err(e) => out.push(
                "index-coherence",
                format!("index query failed for grouped module '{name}': {e}"),
            ),
        }
    }

    // Serialized-IR round-trip fixpoint.
    let j1 = design_to_json(&d1).pretty();
    match Json::parse(&j1).map_err(anyhow::Error::from).and_then(|j| design_from_json(&j)) {
        Ok(d2) => {
            if d2 != d1 {
                out.push("roundtrip-fixpoint", "parsed design differs from original");
            } else if design_to_json(&d2).pretty() != j1 {
                out.push("roundtrip-fixpoint", "re-serialized JSON differs byte-wise");
            }
        }
        Err(e) => out.push(
            "roundtrip-fixpoint",
            format!("output IR JSON failed to parse back: {e:#}"),
        ),
    }

    // Determinism: a second run from the same input is byte-identical.
    let mut d2 = design.clone();
    let mut ctx2 = PassContext::new();
    ctx2.drc_after_each = false;
    match run(&mut d2, &mut ctx2) {
        Ok(()) => {
            if design_to_json(&d2).pretty() != j1 {
                out.push("determinism", "second run produced different IR JSON");
            }
            if ctx2.log != ctx1.log {
                out.push("determinism", "second run produced a different log");
            }
        }
        Err(e) => out.push("determinism", format!("second run failed: {e:#}")),
    }

    out
}

/// Run the default pipeline over a batch of designs on a 1-worker and an
/// 8-worker pool and require byte-identical outputs (the `RSIR_WORKERS=1`
/// vs `8` determinism contract, exercised without mutating process-global
/// environment).
pub fn check_workers_equivalence(designs: &[Design]) -> OracleOutcome {
    let mut out = OracleOutcome::default();
    let job = |d: Design| -> String {
        let mut d = d;
        let mut ctx = PassContext::new();
        match analyze_pipeline(&mut d, &mut ctx) {
            Ok(()) => design_to_json(&d).pretty(),
            Err(e) => format!("error: {e:#}"),
        }
    };
    let serial = Pool::new(1).par_map(designs.to_vec(), job);
    let parallel = Pool::new(8).par_map(designs.to_vec(), job);
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        if a != b {
            out.push(
                "workers-determinism",
                format!("design {i}: 1-worker and 8-worker outputs differ"),
            );
        }
    }
    out
}

/// Canonical multiset of leaf-level channels of a design: every net,
/// resolved through the grouped-module hierarchy from the top, rendered
/// as the sorted set of its leaf-port (and top-boundary) endpoints.
///
/// This is an independent reference elaboration — it never consults
/// `BlockGraph`/`DesignIndex` — so it can adjudicate whether a pipeline
/// preserved connectivity. Clock/reset ports (per the owning module's
/// interfaces) are excluded, like everywhere else in the flow.
///
/// Endpoints deliberately name the leaf *module* and port, not the
/// instance: flatten renames instances (`mid/l1` → `mid__l1`), so the
/// invariant is bisimulation **up to leaf-instance renaming**. The flip
/// side is that rewirings which merely permute two indistinguishable
/// instances of the same leaf module (isomorphic designs) are treated
/// as equivalent — which is the intended equivalence, not a gap: such a
/// permutation is exactly what a restructuring pass is allowed to do.
pub fn leaf_channels(d: &Design) -> BTreeMap<String, usize> {
    let mut nets: BTreeMap<String, Vec<String>> = BTreeMap::new();
    let Some(top) = d.module(&d.top) else {
        return BTreeMap::new();
    };
    for p in &top.ports {
        if is_clockish(top, &p.name) {
            continue;
        }
        nets.entry(format!("/{}", p.name))
            .or_default()
            .push(format!("@top.{}#{}", p.name, p.width));
    }
    walk(d, top, "", &BTreeMap::new(), &mut nets, 0);
    let mut out: BTreeMap<String, usize> = BTreeMap::new();
    for (_key, mut endpoints) in nets {
        if endpoints.is_empty() {
            continue;
        }
        endpoints.sort();
        *out.entry(endpoints.join(" + ")).or_default() += 1;
    }
    out
}

fn is_clockish(m: &Module, port: &str) -> bool {
    matches!(
        m.interface_of(port),
        Some(Interface::Clock { .. }) | Some(Interface::Reset { .. })
    )
}

fn walk(
    d: &Design,
    m: &Module,
    path: &str,
    bind: &BTreeMap<String, String>,
    nets: &mut BTreeMap<String, Vec<String>>,
    depth: usize,
) {
    if depth > 64 {
        return; // recursion guard: DRC permits (degenerate) deep nesting
    }
    let key = |id: &str| {
        bind.get(id)
            .cloned()
            .unwrap_or_else(|| format!("{path}/{id}"))
    };
    for inst in m.instances() {
        let Some(child) = d.module(&inst.module_name) else {
            continue;
        };
        if child.is_grouped() {
            let mut child_bind = BTreeMap::new();
            for c in &inst.connections {
                if let ConnExpr::Id(id) = &c.value {
                    child_bind.insert(c.port.clone(), key(id));
                }
            }
            walk(
                d,
                child,
                &format!("{path}/{}", inst.instance_name),
                &child_bind,
                nets,
                depth + 1,
            );
        } else {
            for c in &inst.connections {
                let ConnExpr::Id(id) = &c.value else { continue };
                if is_clockish(child, &c.port) {
                    continue;
                }
                let width = child.port(&c.port).map(|p| p.width).unwrap_or(0);
                nets.entry(key(id))
                    .or_default()
                    .push(format!("{}.{}#{}", child.name, c.port, width));
            }
        }
    }
}

fn channel_diff(pre: &BTreeMap<String, usize>, post: &BTreeMap<String, usize>) -> String {
    let missing: Vec<&str> = pre
        .iter()
        .filter(|(k, n)| post.get(k.as_str()) != Some(*n))
        .map(|(k, _)| k.as_str())
        .take(3)
        .collect();
    let added: Vec<&str> = post
        .iter()
        .filter(|(k, n)| pre.get(k.as_str()) != Some(*n))
        .map(|(k, _)| k.as_str())
        .take(3)
        .collect();
    format!(
        "leaf channels changed: {} pre vs {} post; lost/changed: [{}]; gained/changed: [{}]",
        pre.len(),
        post.len(),
        missing.join("; "),
        added.join("; ")
    )
}

/// The legacy string-keyed block-graph construction, kept verbatim as
/// reference semantics: the in-tree `BlockGraph::build` is a view over
/// the interned `ModuleConn`, so coherence must be judged against an
/// implementation that shares no code with it (mirrors the gate in
/// `tests/ir_index.rs`).
pub fn reference_block_graph(m: &Module) -> BlockGraph {
    let mut nets: BTreeMap<String, NetInfo> = BTreeMap::new();
    for w in m.wires() {
        nets.entry(w.name.clone()).or_default().width = w.width;
    }
    for p in &m.ports {
        let e = nets.entry(p.name.clone()).or_default();
        e.width = p.width;
        e.endpoints.push(Endpoint::Parent {
            port: p.name.clone(),
        });
    }
    let mut instances = Vec::new();
    for inst in m.instances() {
        instances.push(inst.instance_name.clone());
        for conn in &inst.connections {
            if let ConnExpr::Id(id) = &conn.value {
                nets.entry(id.clone())
                    .or_default()
                    .endpoints
                    .push(Endpoint::Inst {
                        inst: inst.instance_name.clone(),
                        port: conn.port.clone(),
                    });
            }
        }
    }
    BlockGraph { nets, instances }
}

/// Print→parse AST fixpoint for one Verilog source, with an injectable
/// printer (the hook the printer-mutation smoke test uses): parse the
/// source, print every module through `print`, reparse the printed text,
/// and require structural AST equality (spans are ignored by
/// [`VModule`]'s equality).
pub fn check_verilog_fixpoint_with<F>(source: &str, print: F) -> Result<(), String>
where
    F: Fn(&VModule) -> String,
{
    let f1 = parse_file(source).map_err(|e| format!("source failed to parse: {e:#}"))?;
    let printed = f1
        .modules
        .iter()
        .map(&print)
        .collect::<Vec<_>>()
        .join("\n");
    let f2 = parse_file(&printed)
        .map_err(|e| format!("printed text failed to reparse: {e:#}"))?;
    if f1.modules != f2.modules {
        let name = f1
            .modules
            .iter()
            .zip(&f2.modules)
            .find(|(a, b)| a != b)
            .map(|(a, _)| a.name.clone())
            .unwrap_or_else(|| {
                format!(
                    "<module count {} vs {}>",
                    f1.modules.len(),
                    f2.modules.len()
                )
            });
        return Err(format!(
            "print→parse AST fixpoint broken at module '{name}'"
        ));
    }
    Ok(())
}

/// Digest of a design quotiented by cosmetic naming: metadata (design,
/// module, instance) is stripped, interface cosmetic names / clock
/// associations are normalized and interfaces sorted, and every wire of
/// every grouped module is renamed to a canonical name derived from its
/// endpoint signature (sorted `instance.port` endpoints plus width).
///
/// Two pipeline outputs that differ only in wire names (`flatten` mints
/// `{inst}__{wire}`, `rebuild` mints `w_{inst}_{port}`), instance
/// metadata, or interface labels land in the same class; any structural
/// difference — a port, a width, a connection, a leaf source byte —
/// changes it.
pub fn digest_class(d: &Design) -> u64 {
    let mut d = d.clone();
    d.metadata = JsonObj::new();
    for m in d.modules.values_mut() {
        m.metadata = JsonObj::new();
        canon_interfaces(m);
        if m.is_grouped() {
            canon_grouped(m);
        }
    }
    synthetic::digest(&d)
}

/// Normalize interface cosmetic fields: handshake/feedforward/
/// non-pipeline names are derived from their ports (iface-infer mints
/// `{port}_inferred`, pragma patterns mint the bundle name — same
/// structure, different label), handshake clock association is an
/// annotation not a connection, and list order is canonicalized.
fn canon_interfaces(m: &mut Module) {
    for i in &mut m.interfaces {
        match i {
            Interface::Handshake {
                name,
                data,
                valid,
                clk,
                ..
            } => {
                data.sort();
                *name = valid.clone();
                *clk = None;
            }
            Interface::Feedforward { name, ports } | Interface::NonPipeline { name, ports } => {
                ports.sort();
                *name = ports.first().cloned().unwrap_or_default();
            }
            Interface::Clock { .. } | Interface::Reset { .. } => {}
        }
    }
    m.interfaces.sort_by_key(|i| format!("{i:?}"));
}

/// Canonically rename the wires of a grouped module and sort its
/// instances/connections. In a DRC-clean design every wire has exactly
/// two instance endpoints, so the endpoint signature is unique per wire
/// (the dup-connection rule forbids ties) and the renaming is a
/// bijection independent of the incoming names.
fn canon_grouped(m: &mut Module) {
    let mut sig: BTreeMap<String, Vec<String>> = BTreeMap::new();
    for inst in m.instances() {
        for c in &inst.connections {
            if let ConnExpr::Id(id) = &c.value {
                sig.entry(id.clone())
                    .or_default()
                    .push(format!("{}.{}", inst.instance_name, c.port));
            }
        }
    }
    let mut keyed: Vec<(String, String, u32)> = m
        .wires()
        .iter()
        .map(|w| {
            let mut eps = sig.remove(&w.name).unwrap_or_default();
            eps.sort();
            (
                format!("{}#{}", eps.join(" + "), w.width),
                w.name.clone(),
                w.width,
            )
        })
        .collect();
    keyed.sort();
    let rename: BTreeMap<&str, String> = keyed
        .iter()
        .enumerate()
        .map(|(i, (_, old, _))| (old.as_str(), format!("__rsw{i}")))
        .collect();
    let new_wires: Vec<Wire> = keyed
        .iter()
        .enumerate()
        .map(|(i, (_, _, w))| Wire {
            name: format!("__rsw{i}"),
            width: *w,
        })
        .collect();
    let renamed: BTreeMap<String, String> = rename
        .into_iter()
        .map(|(k, v)| (k.to_string(), v))
        .collect();
    *m.wires_mut() = new_wires;
    for inst in m.instances_mut() {
        inst.metadata = JsonObj::new();
        for c in &mut inst.connections {
            if let ConnExpr::Id(id) = &mut c.value {
                if let Some(nn) = renamed.get(id) {
                    *id = nn.clone();
                }
            }
        }
        inst.connections.sort_by(|a, b| a.port.cmp(&b.port));
    }
    m.instances_mut()
        .sort_by(|a, b| a.instance_name.cmp(&b.instance_name));
}

/// Run the Verilog round-trip oracle over a plan with the production
/// printer. See [`check_verilog_roundtrip_with`].
pub fn check_verilog_roundtrip(plan: &DesignPlan) -> OracleOutcome {
    check_verilog_roundtrip_with(plan, crate::verilog::printer::print_module)
}

/// The Verilog round-trip oracle: materialize `plan` as source text, then
/// require —
///
/// 1. **verilog-fixpoint** — each Verilog source survives a print→parse
///    round trip through `print` with an identical AST;
/// 2. **import-bisimulation** — importing the text
///    ([`crate::plugins::importer::import_mixed`]) and running the
///    analyze pipeline reconstructs the leaf-channel multiset of the
///    directly-materialized design;
/// 3. **export-reimport** — exporting that result and importing the
///    export converges to the same leaf-channel multiset and the same
///    [`digest_class`].
///
/// `print` is only used for invariant 1 (injectable so a deliberately
/// broken printer is provably caught); invariants 2–3 exercise the real
/// importer/exporter.
pub fn check_verilog_roundtrip_with<F>(plan: &DesignPlan, print: F) -> OracleOutcome
where
    F: Fn(&VModule) -> String,
{
    let mut out = OracleOutcome::default();
    let srcs = synthetic::materialize_sources(plan);

    for (i, src) in srcs.verilog.iter().enumerate() {
        if let Err(e) = check_verilog_fixpoint_with(src, &print) {
            out.push("verilog-fixpoint", format!("verilog source {i}: {e}"));
        }
    }
    if !out.is_clean() {
        return out; // the text layer is broken; downstream noise helps nobody
    }

    let direct = synthetic::materialize(plan);
    let ref_channels = leaf_channels(&direct);

    let mut run1 = match crate::plugins::importer::import_mixed(
        &srcs.top,
        &srcs.verilog,
        &srcs.xci,
        &srcs.xo,
    ) {
        Ok(d) => d,
        Err(e) => {
            out.push(
                "import-bisimulation",
                format!("materialized sources failed to import: {e:#}"),
            );
            return out;
        }
    };
    let mut ctx1 = PassContext::new();
    if let Err(e) = analyze_pipeline(&mut run1, &mut ctx1) {
        out.push(
            "pipeline-runs",
            format!("pipeline failed on imported text: {e:#}"),
        );
        return out;
    }
    let ch1 = leaf_channels(&run1);
    if ch1 != ref_channels {
        out.push("import-bisimulation", channel_diff(&ref_channels, &ch1));
    }

    let bundle = match crate::plugins::exporter::export(&run1) {
        Ok(b) => b,
        Err(e) => {
            out.push("export-reimport", format!("export failed: {e:#}"));
            return out;
        }
    };
    let mut verilog2 = Vec::new();
    let mut xci2 = Vec::new();
    for (name, content) in &bundle.files {
        if name.ends_with(".v") {
            // Drop files carrying no modules (e.g. an empty design_top.v
            // for a leaf-only design); keep unparsable ones so the
            // importer surfaces the error as a violation.
            if parse_file(content)
                .map(|f| f.modules.is_empty())
                .unwrap_or(false)
            {
                continue;
            }
            verilog2.push(content.clone());
        } else if name.ends_with(".xci") {
            xci2.push(content.clone());
        }
    }
    let mut run2 =
        match crate::plugins::importer::import_mixed(&run1.top, &verilog2, &xci2, &[]) {
            Ok(d) => d,
            Err(e) => {
                out.push(
                    "export-reimport",
                    format!("exported sources failed to re-import: {e:#}"),
                );
                return out;
            }
        };
    let mut ctx2 = PassContext::new();
    if let Err(e) = analyze_pipeline(&mut run2, &mut ctx2) {
        out.push(
            "export-reimport",
            format!("pipeline failed on re-imported export: {e:#}"),
        );
        return out;
    }
    let ch2 = leaf_channels(&run2);
    if ch2 != ref_channels {
        out.push("export-reimport", channel_diff(&ref_channels, &ch2));
    }
    let (c1, c2) = (digest_class(&run1), digest_class(&run2));
    if c1 != c2 {
        out.push(
            "export-reimport",
            format!("digest class diverges after export→re-import: {c1:#018x} vs {c2:#018x}"),
        );
    }
    out
}

/// The daemon's determinism invariant, checked differentially: boot a
/// real `rsir serve` daemon (unix socket, 4 workers, warm caches on) and
/// require that every response byte matches the one-shot lane
/// ([`client::run_batch_local`](crate::server::client::run_batch_local),
/// which runs with caches disabled).
///
/// Per input design the batch submits a `pipeline` job and a `flow` job
/// (devices and SA settings varied by index, inline IR payloads), plus
/// warm-path resubmits of the first design (exercising the `results`
/// memo) — split across **two concurrent connections**, so job
/// completion order races freely while the bytes may not. A deliberately
/// slow `flow` job is canceled mid-flight and then resubmitted; the
/// canceled response may be either the typed `canceled` error or (if the
/// job won the race) the full canonical result, but the *resubmit* must
/// again match the one-shot lane exactly — a canceled job must never
/// poison the caches.
///
/// Violations: **daemon-equivalence** (byte mismatch) and
/// **daemon-protocol** (connection/response-shape failures).
pub fn check_daemon_equivalence(designs: &[Design]) -> OracleOutcome {
    use crate::server::client::{run_batch_local, run_batch_remote};
    use crate::server::protocol::{err_line, parse_line, ErrorCode};
    use crate::server::{scratch_socket, Bind, ServeConfig, Server};
    use std::time::Duration;

    let mut out = OracleOutcome::default();
    if designs.is_empty() {
        return out;
    }

    // Two request batches, one per connection: pipelines + the cancel
    // scenario on A, flows + warm resubmits on B.
    let mut lines_a: Vec<String> = Vec::new();
    let mut lines_b: Vec<String> = Vec::new();
    let flow_line = |id: &str, dj: &str, i: usize| {
        let device = if i % 2 == 0 { "u250" } else { "u280" };
        let sa = i % 3 != 0;
        format!(
            r#"{{"id":"{id}","type":"flow","params":{{"design":{dj},"device":"{device}","sa_refine":{sa},"seed":7}}}}"#
        )
    };
    for (i, d) in designs.iter().enumerate() {
        let dj = design_to_json(d).dump();
        lines_a.push(format!(
            r#"{{"id":"p{i}","type":"pipeline","params":{{"design":{dj}}}}}"#
        ));
        lines_b.push(flow_line(&format!("f{i}"), &dj, i));
    }
    // Warm-path resubmits of design 0: identical params, new ids — the
    // daemon answers from its results memo, the one-shot lane recomputes.
    let dj0 = design_to_json(&designs[0]).dump();
    lines_b.push(format!(
        r#"{{"id":"p0r","type":"pipeline","params":{{"design":{dj0}}}}}"#
    ));
    lines_b.push(flow_line("f0r", &dj0, 0));
    // Mid-flight cancellation: a deliberately heavy flow, a cancel racing
    // it on the same connection, and a resubmit that must be unpoisoned.
    // Skipped for single-design batches so the fuzz minimizer's per-plan
    // property stays cheap (the scenario is batch-level, not per-design).
    if designs.len() >= 2 {
        let slow = r#"{"id":"slow","type":"flow","params":{"bench":"cnn:13x8","seed":7}}"#;
        let slow_resubmit =
            r#"{"id":"slowr","type":"flow","params":{"bench":"cnn:13x8","seed":7}}"#;
        lines_a.push(slow.to_string());
        lines_a.push(r#"{"id":"c-slow","type":"cancel","params":{"job":"slow"}}"#.to_string());
        lines_a.push(slow_resubmit.to_string());
    }

    // Reference side: the one-shot lane, sequential, caches disabled.
    let expect_a = run_batch_local(&lines_a);
    let expect_b = run_batch_local(&lines_b);

    // Daemon side.
    let mut cfg = ServeConfig::new(Bind::Unix(scratch_socket("oracle")));
    cfg.workers = 4;
    cfg.quiet = true;
    let server = match Server::bind(cfg) {
        Ok(s) => s,
        Err(e) => {
            out.push("daemon-protocol", format!("server failed to bind: {e:#}"));
            return out;
        }
    };
    let endpoint = server.endpoint();
    let server_thread = std::thread::spawn(move || server.run());
    let timeout = Duration::from_secs(300);
    let (got_a, got_b) = std::thread::scope(|s| {
        let ep_a = endpoint.clone();
        let la = &lines_a;
        let a = s.spawn(move || run_batch_remote(&ep_a, la, timeout));
        let ep_b = endpoint.clone();
        let lb = &lines_b;
        let b = s.spawn(move || run_batch_remote(&ep_b, lb, timeout));
        (a.join(), b.join())
    });

    let compare = |requests: &[String],
                       expected: &[String],
                       got: std::thread::Result<anyhow::Result<Vec<String>>>,
                       out: &mut OracleOutcome| {
        let got = match got {
            Ok(Ok(g)) => g,
            Ok(Err(e)) => {
                out.push("daemon-protocol", format!("client batch failed: {e:#}"));
                return;
            }
            Err(_) => {
                out.push("daemon-protocol", "client thread panicked".to_string());
                return;
            }
        };
        if got.len() != expected.len() {
            out.push(
                "daemon-protocol",
                format!("{} responses for {} requests", got.len(), expected.len()),
            );
            return;
        }
        for ((req, want), have) in requests.iter().zip(expected).zip(&got) {
            let id = parse_line(req).id;
            let id_str = id.dump();
            if id_str == "\"slow\"" {
                // Raced by the cancel: either outcome is legal, but it
                // must be one of exactly these two byte strings.
                let canceled = err_line(&id, ErrorCode::Canceled, "job canceled");
                if have != want && *have != canceled {
                    out.push(
                        "daemon-equivalence",
                        format!("job {id_str}: neither completed nor canceled bytes: {have}"),
                    );
                }
                continue;
            }
            if id_str == "\"c-slow\"" {
                // Legal answers depend on the race: acknowledged cancel,
                // or unknown-job if `slow` already finished.
                let acked = r#"{"id":"c-slow","ok":true,"result":{"canceled":"slow"}}"#;
                let done = err_line(&id, ErrorCode::UnknownJob, "job 'slow' already completed");
                if have != acked && *have != done {
                    out.push(
                        "daemon-equivalence",
                        format!("cancel {id_str}: unexpected response: {have}"),
                    );
                }
                continue;
            }
            if have != want {
                out.push(
                    "daemon-equivalence",
                    format!("job {id_str}: daemon bytes differ from one-shot\n  one-shot: {want}\n  daemon:   {have}"),
                );
            }
        }
    };
    compare(&lines_a, &expect_a, got_a, &mut out);
    compare(&lines_b, &expect_b, got_b, &mut out);

    // Orderly shutdown: ack received and the server thread exits clean.
    match run_batch_remote(
        &endpoint,
        &[r#"{"id":"down","type":"shutdown"}"#.to_string()],
        Duration::from_secs(30),
    ) {
        Ok(ack) if ack.first().map(|l| l.contains("shutting_down")) == Some(true) => {}
        Ok(ack) => out.push(
            "daemon-protocol",
            format!("unexpected shutdown ack: {ack:?}"),
        ),
        Err(e) => out.push("daemon-protocol", format!("shutdown failed: {e:#}")),
    }
    match server_thread.join() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => out.push("daemon-protocol", format!("server exited with error: {e:#}")),
        Err(_) => out.push("daemon-protocol", "server thread panicked".to_string()),
    }
    out
}

/// The fault-resilience invariant: with a [`FaultPlan`] armed against
/// the daemon's production sites, **every request still terminates
/// within its deadline with either a typed error envelope or a result
/// byte-identical to the fault-free one-shot lane** — never a wrong
/// answer, a hang, or a dead daemon.
///
/// Mechanics: the fault-free expectation is computed *before* arming
/// (the one-shot lane, caches disabled); the plan is then armed for the
/// daemon scope only and disarmed again before the orderly-shutdown
/// check, so shutdown itself is fault-free. The client runs with a
/// generous [`RetryPolicy`] — every arm fires exactly once, so the
/// bounded injection count guarantees reconnect-and-resubmit converges.
///
/// Violations all report as **fault-resilience**, carrying the armed
/// plan, which arms actually fired, and the offending response bytes.
///
/// [`FaultPlan`]: crate::testing::faults::FaultPlan
/// [`RetryPolicy`]: crate::server::client::RetryPolicy
pub fn check_fault_resilience(
    designs: &[Design],
    plan: &crate::testing::faults::FaultPlan,
) -> OracleOutcome {
    use crate::server::client::{run_batch_local, run_batch_remote, run_batch_remote_with, RetryPolicy};
    use crate::server::protocol::{parse_line, ErrorCode};
    use crate::server::{scratch_socket, Bind, ServeConfig, Server};
    use crate::testing::faults;
    use std::time::Duration;

    let mut out = OracleOutcome::default();
    if designs.is_empty() {
        return out;
    }

    // One pipeline + one flow job per design, plus a warm resubmit of
    // design 0 (the results-cache path is where corruption faults bite).
    let mut lines: Vec<String> = Vec::new();
    for (i, d) in designs.iter().enumerate() {
        let dj = design_to_json(d).dump();
        lines.push(format!(
            r#"{{"id":"p{i}","type":"pipeline","params":{{"design":{dj}}}}}"#
        ));
        let device = if i % 2 == 0 { "u250" } else { "u280" };
        lines.push(format!(
            r#"{{"id":"f{i}","type":"flow","params":{{"design":{dj},"device":"{device}","sa_refine":false,"seed":7}}}}"#
        ));
    }
    let dj0 = design_to_json(&designs[0]).dump();
    lines.push(format!(
        r#"{{"id":"p0r","type":"pipeline","params":{{"design":{dj0}}}}}"#
    ));

    // Fault-free reference, computed before arming.
    let expected = run_batch_local(&lines);

    let guard = faults::arm(plan);

    let mut cfg = ServeConfig::new(Bind::Unix(scratch_socket("faults")));
    cfg.workers = 2;
    cfg.quiet = true;
    let server = match Server::bind(cfg) {
        Ok(s) => s,
        Err(e) => {
            drop(guard);
            out.push("fault-resilience", format!("server failed to bind: {e:#}"));
            return out;
        }
    };
    let endpoint = server.endpoint();
    let server_thread = std::thread::spawn(move || server.run());

    // Generous but finite: enough reconnects to outlast every possible
    // connection-killing arm in a 3-arm plan, under one hard deadline.
    let policy = RetryPolicy {
        attempts: 6,
        base_delay: Duration::from_millis(10),
        max_delay: Duration::from_millis(100),
    };
    let got = run_batch_remote_with(&endpoint, &lines, Duration::from_secs(300), &policy);

    let fired = faults::fired_log().join(", ");
    let context = format!(
        "plan: [{}]; fired: [{fired}]",
        plan.render()
    );
    // Disarm before the shutdown round-trip.
    drop(guard);

    // Is `have` a well-formed typed error envelope for the request `req`?
    let typed_error = |req: &str, have: &str| -> std::result::Result<(), String> {
        let Ok(j) = Json::parse(have) else {
            return Err("response is not valid JSON".to_string());
        };
        let Some(o) = j.as_obj() else {
            return Err("response is not a JSON object".to_string());
        };
        let want_id = parse_line(req).id.dump();
        let have_id = o.get("id").cloned().unwrap_or(Json::Null).dump();
        if have_id != want_id {
            return Err(format!("error envelope id {have_id} != request id {want_id}"));
        }
        if o.get("ok") != Some(&Json::Bool(false)) {
            return Err("non-identical response does not have \"ok\":false".to_string());
        }
        let code = o
            .get("error")
            .and_then(|e| e.as_obj())
            .and_then(|e| e.get("code"))
            .and_then(|c| c.as_str())
            .unwrap_or("");
        match ErrorCode::from_wire(code) {
            Some(_) => Ok(()),
            None => Err(format!("unknown error code '{code}'")),
        }
    };

    match got {
        Err(e) => out.push(
            "fault-resilience",
            format!("client batch did not terminate cleanly: {e:#} ({context})"),
        ),
        Ok(got) if got.len() != expected.len() => out.push(
            "fault-resilience",
            format!(
                "{} responses for {} requests ({context})",
                got.len(),
                expected.len()
            ),
        ),
        Ok(got) => {
            for ((req, want), have) in lines.iter().zip(&expected).zip(&got) {
                if have == want {
                    continue;
                }
                if let Err(why) = typed_error(req, have) {
                    out.push(
                        "fault-resilience",
                        format!(
                            "request {}: neither byte-identical nor a typed error: {why} ({context})\n  one-shot: {want}\n  daemon:   {have}",
                            parse_line(req).id.dump()
                        ),
                    );
                }
            }
        }
    }

    // The daemon must still be alive and shut down orderly — a
    // fault-killed process or a wedged queue fails here.
    match run_batch_remote(
        &endpoint,
        &[r#"{"id":"down","type":"shutdown"}"#.to_string()],
        Duration::from_secs(30),
    ) {
        Ok(ack) if ack.first().map(|l| l.contains("shutting_down")) == Some(true) => {}
        Ok(ack) => out.push(
            "fault-resilience",
            format!("unexpected shutdown ack after faults: {ack:?} ({context})"),
        ),
        Err(e) => out.push(
            "fault-resilience",
            format!("daemon unreachable for shutdown after faults: {e:#} ({context})"),
        ),
    }
    match server_thread.join() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => out.push(
            "fault-resilience",
            format!("server exited with error: {e:#} ({context})"),
        ),
        Err(_) => out.push(
            "fault-resilience",
            format!("server thread panicked ({context})"),
        ),
    }
    out
}

/// Deterministic fingerprint of one flow outcome: folds the post-flow
/// design IR (compact JSON bytes) with every deterministic field of the
/// report — baseline/optimized [`ImplReport`](crate::eda::vivado::ImplReport)
/// debug renderings (which print every float exactly), partition and
/// relay-station counts, floorplan wirelength bits, evaluator name, and
/// the full log. Wall-clock instrumentation (`report.stats`, per-pass
/// times in `report.analysis`) is deliberately excluded: the fingerprint
/// is equal iff the *outputs* are byte-identical.
pub fn flow_fingerprint(design: &Design, report: &FlowReport) -> u64 {
    let mut f = Fnv::new();
    f.write_str(&design_to_json(design).dump());
    match &report.baseline {
        Ok(b) => f.write_bool(true).write_str(&format!("{b:?}")),
        Err(e) => f.write_bool(false).write_str(&format!("{e:#}")),
    };
    f.write_str(&format!("{:?}", report.optimized));
    f.write_usize(report.relay_stations);
    f.write_usize(report.partitions);
    f.write_f64(report.floorplan_wirelength);
    f.write_str(report.evaluator_used);
    for line in &report.log {
        f.write_str(line);
    }
    f.finish()
}

/// The canonical oracle edit: bump the first (BTreeMap-ordered) leaf
/// module's `timing.internal_ns` metadata by a fixed delta. Dirties
/// exactly the subtree digests on the path from that leaf to the top —
/// the smallest edit that forces re-characterization, re-flattening of
/// the dirty cone, and a delta STA, while leaving placement keys
/// untouched. Returns `false` when the design has no leaf to edit.
pub fn perturb_leaf_timing(d: &mut Design) -> bool {
    let Some(leaf) = d
        .modules
        .values()
        .find(|m| !m.is_grouped())
        .map(|m| m.name.clone())
    else {
        return false;
    };
    let m = d.module_mut(&leaf).unwrap();
    let old = m
        .metadata
        .get("timing")
        .and_then(|t| t.at("internal_ns"))
        .and_then(|j| j.as_f64())
        .unwrap_or(2.2);
    let mut t = JsonObj::new();
    t.insert("internal_ns", Json::num(old + 0.41));
    m.metadata.insert("timing", Json::Obj(t));
    true
}

/// Run the flow on a clone of `design` (optionally through a shared
/// [`StageMemo`]) and fingerprint the outcome; a flow *error* folds the
/// rendered error string instead, so Err-vs-Err runs compare too.
fn reflow_fp(
    design: &Design,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
    stage: Option<Arc<StageMemo>>,
) -> u64 {
    let mut d = design.clone();
    let mut warm = FlowWarm {
        stage,
        ..Default::default()
    };
    match run_hlps_warm(&mut d, dev, cfg, &mut warm) {
        Ok(report) => flow_fingerprint(&d, &report),
        Err(e) => {
            let mut f = Fnv::new();
            f.write_str("flow-error").write_str(&format!("{e:#}"));
            f.finish()
        }
    }
}

/// [`check_incremental_reflow_with`] on the default oracle rig: the
/// `u250` device and the default flow config with SA refinement off
/// (the ILP floorplan path; SA-on runs are covered by the staged
/// explore/daemon tests, which share the same memo code paths).
pub fn check_incremental_reflow(design: &Design) -> OracleOutcome {
    let dev = crate::device::builtin::by_name("u250").expect("builtin device");
    let cfg = FlowConfig {
        sa_refine: false,
        ..FlowConfig::default()
    };
    check_incremental_reflow_with(design, &dev, &cfg)
}

/// The incremental re-flow oracle — the determinism contract of the
/// whole memoization engine, checked differentially against from-scratch
/// runs. One [`StageMemo`] is shared across three warm runs and every
/// fingerprint must match its memo-free reference:
///
/// * **reflow-cold-identity** — the first run through an empty memo
///   (every stage misses, every stage *inserts*) equals the cold run.
/// * **reflow-edit-identity** — after [`perturb_leaf_timing`], the run
///   through the now-polluted memo (placement hits, characterization /
///   flatten / STA partially hit) equals a from-scratch run on the
///   edited design.
/// * **reflow-pollution-identity** — the *original* design re-run
///   through the doubly-polluted memo still equals the original cold
///   run: entries for the edited design must never shadow entries for
///   the original (key soundness).
pub fn check_incremental_reflow_with(
    design: &Design,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
) -> OracleOutcome {
    let mut out = OracleOutcome::default();
    let memo = Arc::new(StageMemo::new(64));

    let cold = reflow_fp(design, dev, cfg, None);
    let warm_cold = reflow_fp(design, dev, cfg, Some(memo.clone()));
    if warm_cold != cold {
        out.push(
            "reflow-cold-identity",
            format!("memoized first run diverges from cold run: {warm_cold:#018x} vs {cold:#018x}"),
        );
    }

    let mut edited = design.clone();
    if perturb_leaf_timing(&mut edited) {
        let edited_cold = reflow_fp(&edited, dev, cfg, None);
        let edited_warm = reflow_fp(&edited, dev, cfg, Some(memo.clone()));
        if edited_warm != edited_cold {
            out.push(
                "reflow-edit-identity",
                format!(
                    "re-flow after leaf edit diverges from from-scratch: \
                     {edited_warm:#018x} vs {edited_cold:#018x}"
                ),
            );
        }
    }

    let again = reflow_fp(design, dev, cfg, Some(memo));
    if again != cold {
        out.push(
            "reflow-pollution-identity",
            format!(
                "original design re-run through polluted memo diverges: \
                 {again:#018x} vs {cold:#018x}"
            ),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{GroupedBuilder, LeafBuilder};

    /// a0:A --hs--> mid(m0:M) --hs--> (exported), nested one level.
    fn nested_sample() -> Design {
        let mut d = Design::new("Top");
        d.add(
            LeafBuilder::verilog_stub("A")
                .clk_rst()
                .handshake("o", Dir::Out, 8)
                .build(),
        );
        d.add(
            LeafBuilder::verilog_stub("M")
                .clk_rst()
                .handshake("i", Dir::In, 8)
                .build(),
        );
        let mid = GroupedBuilder::new("Mid")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .port("s", Dir::In, 8)
            .port("s_vld", Dir::In, 1)
            .port("s_rdy", Dir::Out, 1)
            .iface(Interface::Handshake {
                name: "s".into(),
                data: vec!["s".into()],
                valid: "s_vld".into(),
                ready: "s_rdy".into(),
                clk: Some("ap_clk".into()),
            })
            .inst(
                "m0",
                "M",
                &[
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                    ("i", "s"),
                    ("i_vld", "s_vld"),
                    ("i_rdy", "s_rdy"),
                ],
            )
            .build();
        d.add(mid);
        let top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .wire("w", 8)
            .wire("w_vld", 1)
            .wire("w_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                    ("o", "w"),
                    ("o_vld", "w_vld"),
                    ("o_rdy", "w_rdy"),
                ],
            )
            .inst(
                "mid",
                "Mid",
                &[
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                    ("s", "w"),
                    ("s_vld", "w_vld"),
                    ("s_rdy", "w_rdy"),
                ],
            )
            .build();
        d.add(top);
        d
    }

    #[test]
    fn leaf_channels_resolve_through_hierarchy() {
        let d = nested_sample();
        let ch = leaf_channels(&d);
        // The a0.o -> (mid) m0.i handshake resolves to direct leaf pairs.
        assert_eq!(ch.get("A.o#8 + M.i#8"), Some(&1), "{ch:?}");
        assert_eq!(ch.get("A.o_vld#1 + M.i_vld#1"), Some(&1));
        assert_eq!(ch.get("A.o_rdy#1 + M.i_rdy#1"), Some(&1));
        // Clock/reset broadcast is excluded.
        assert!(ch.keys().all(|k| !k.contains("ap_clk")), "{ch:?}");
    }

    #[test]
    fn pipeline_preserves_nested_sample() {
        let out = check_pipeline(&nested_sample());
        assert!(out.is_clean(), "{}", out.render());
    }

    #[test]
    fn dirty_input_reports_precondition() {
        let mut d = nested_sample();
        d.module_mut("Top")
            .unwrap()
            .instances_mut()
            .push(Instance::new("ghost", "NoSuchModule"));
        let out = check_pipeline(&d);
        assert_eq!(out.violated(), vec!["input-drc"]);
    }

    #[test]
    fn workers_equivalence_on_samples() {
        let designs = vec![nested_sample(), nested_sample()];
        let out = check_workers_equivalence(&designs);
        assert!(out.is_clean(), "{}", out.render());
    }

    #[test]
    fn incremental_reflow_clean_on_nested_sample() {
        let out = check_incremental_reflow(&nested_sample());
        assert!(out.is_clean(), "{}", out.render());
    }

    #[test]
    fn perturb_leaf_timing_moves_the_digest() {
        let a = nested_sample();
        let mut b = a.clone();
        assert!(perturb_leaf_timing(&mut b));
        assert_ne!(synthetic::digest(&a), synthetic::digest(&b));
        // The edit is deterministic: applying it to a fresh clone lands
        // on the same design bytes.
        let mut c = a.clone();
        assert!(perturb_leaf_timing(&mut c));
        assert_eq!(synthetic::digest(&b), synthetic::digest(&c));
    }

    #[test]
    fn digest_class_quotients_wire_names_and_metadata() {
        let a = nested_sample();
        let mut b = nested_sample();
        // Rename Top's wires (flatten-style) and decorate with metadata:
        // both are cosmetic, so the class must not move.
        let top = b.module_mut("Top").unwrap();
        for w in top.wires_mut() {
            w.name = format!("mid__{}", w.name);
        }
        for inst in top.instances_mut() {
            inst.metadata.insert("floorplan", Json::str("SLOT_X0Y0"));
            for c in &mut inst.connections {
                if let ConnExpr::Id(id) = &mut c.value {
                    if id.starts_with('w') {
                        *id = format!("mid__{id}");
                    }
                }
            }
        }
        assert_ne!(synthetic::digest(&a), synthetic::digest(&b));
        assert_eq!(digest_class(&a), digest_class(&b));
        // A structural change (an extra wire) does move the class.
        let mut c = nested_sample();
        c.module_mut("Top")
            .unwrap()
            .wires_mut()
            .push(Wire {
                name: "dangling".into(),
                width: 4,
            });
        assert_ne!(digest_class(&a), digest_class(&c));
    }

    #[test]
    fn fixpoint_holds_for_printer_and_catches_mutations() {
        let src = "module M (\n  input wire a,\n  output wire [7:0] y\n);\n  wire t;\n  sub s0 (\n    .i(a),\n    .o(t)\n  );\nendmodule\n";
        check_verilog_fixpoint_with(src, crate::verilog::printer::print_module)
            .expect("production printer is a fixpoint");
        // A printer that drops the last port must be caught.
        let broken = |m: &VModule| {
            let mut m2 = m.clone();
            m2.ports.pop();
            crate::verilog::printer::print_module(&m2)
        };
        assert!(check_verilog_fixpoint_with(src, broken).is_err());
    }

    #[test]
    fn verilog_roundtrip_clean_on_generated_plans() {
        use crate::designs::synthetic::{DesignGen, SyntheticConfig};
        use crate::util::rng::Rng;
        let gen = DesignGen {
            cfg: SyntheticConfig::default(),
        };
        let mut rng = Rng::new(7);
        for case in 0..6 {
            let plan = gen.generate(&mut rng);
            let out = check_verilog_roundtrip(&plan);
            assert!(out.is_clean(), "case {case}: {}", out.render());
        }
    }

    #[test]
    fn broken_printer_trips_verilog_fixpoint() {
        use crate::designs::synthetic::{DesignGen, SyntheticConfig};
        use crate::util::rng::Rng;
        let gen = DesignGen {
            cfg: SyntheticConfig::default(),
        };
        let mut rng = Rng::new(7);
        let plan = gen.generate(&mut rng);
        let broken = |m: &VModule| {
            let mut m2 = m.clone();
            m2.ports.pop();
            crate::verilog::printer::print_module(&m2)
        };
        let out = check_verilog_roundtrip_with(&plan, broken);
        assert!(
            out.violated().contains(&"verilog-fixpoint"),
            "{}",
            out.render()
        );
    }
}

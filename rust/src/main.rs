//! `rsir` — RapidStream IR command-line driver.
//!
//! ```text
//! rsir devices                         list built-in virtual devices
//! rsir flow --bench llama2 --device u280 [--util 0.7] [--pjrt]
//!           [--sa-workers N]           parallel SA chains (deterministic)
//! rsir passes                          list registered passes + pipelines
//! rsir pipeline <spec> [--bench id]    run a pass composition by name
//! rsir table1                          Table 1: HLS-frontend LoC
//! rsir table2 [--only <substr>]        Table 2: frequency improvements
//! rsir fig12 [--device vhk158]         Figure 12: floorplan exploration
//! rsir fig13                           Figure 13: parallel synthesis
//! rsir dse [--bench llama2] [--device u280] [--utils 0.6,0.7,0.8]
//!          [--grids 1,2] [--steps 60,120] [--strategies full,dies]
//!          [--no-warm] [--out dse.json] multi-dimensional design-space
//!                                      exploration: sweep utilization ×
//!                                      slot grid × pipelining × SA
//!                                      budget, SA warm-started along the
//!                                      budget axis, and print/write the
//!                                      Pareto front (byte-identical at
//!                                      any worker count)
//! rsir import <top> <file.v>...        import Verilog into IR JSON
//! rsir export <ir.json> <outdir>       export IR to Verilog + XDC
//! rsir fuzz [--seed N] [--cases M] [--out f.json] [--digests]
//!                                      run generated designs through the
//!                                      differential oracle suite; shrink
//!                                      and write counterexamples
//!                                      (--digests --out f.txt writes the
//!                                      pinnable golden-digest file)
//! rsir fuzz --verilog [--seed N] [--cases M] [--out f.v]
//!                                      Verilog round-trip lane: each plan
//!                                      is materialized as source text,
//!                                      imported, analyzed, exported and
//!                                      re-imported; failures shrink to a
//!                                      minimal .v counterexample
//! rsir fuzz --reflow [--seed N] [--cases M] [--out f.json]
//!                                      incremental re-flow lane: each
//!                                      design runs the HLPS flow through
//!                                      a shared stage memo (cold, after
//!                                      a leaf edit, after pollution) and
//!                                      every outcome must be bit-for-bit
//!                                      identical to a from-scratch run
//! rsir fuzz --daemon [--seed N] [--cases M] [--out f.json]
//!                                      daemon-equivalence lane: boot a
//!                                      real `rsir serve`, submit every
//!                                      generated design over concurrent
//!                                      connections (with warm-cache
//!                                      resubmits and a mid-flight
//!                                      cancellation) and require every
//!                                      response byte-identical to the
//!                                      one-shot lane
//! rsir fuzz --faults [--seed N] [--cases M] [--out f.json]
//!                                      fault-resilience lane: per case,
//!                                      arm a seeded fault plan (injected
//!                                      IO errors/panics/short
//!                                      reads/delays/cache corruption)
//!                                      against a real daemon and require
//!                                      every response to be a typed
//!                                      error or byte-identical to the
//!                                      fault-free one-shot lane; shrinks
//!                                      the (design, fault-plan) pair
//! rsir serve (--socket p | --port n) [--workers N] [--cache N]
//!           [--max-queue N] [--quiet]  resident compilation daemon:
//!                                      line-delimited JSON jobs over a
//!                                      unix socket or loopback TCP, warm
//!                                      cross-request caches
//! rsir submit (--socket p | --port n | --local) [--file reqs.jsonl]
//!           [--timeout-ms N] [--retries N] [--retry-ms N]
//!                                      ship request lines (stdin or
//!                                      --file) to a daemon and print one
//!                                      response line per request;
//!                                      --local runs the identical
//!                                      one-shot lane without a daemon.
//!                                      Transport failures reconnect and
//!                                      resubmit with capped exponential
//!                                      backoff: --retries attempts
//!                                      (default 4) starting at
//!                                      --retry-ms (default 25, capped at
//!                                      16x)
//! rsir version                         print the crate version (also
//!                                      reported in the daemon `hello`)
//! ```
//!
//! The global `--workers N` flag (or the `RSIR_WORKERS` environment
//! variable) sizes the work-stealing pool that fans out Table 2 rows, the
//! Figure 12 sweep points, and the Figure 13 per-slot synthesis jobs;
//! unset, it defaults to the machine's available parallelism. Results are
//! deterministic for a given seed regardless of the worker count.

use anyhow::{bail, Result};
use rsir::coordinator::{dse, explore, flow, parallel_synth, report};
use rsir::device::builtin;
use rsir::passes::{registry, DrcOutcome, PassContext};
use rsir::util::bench::Table;
use rsir::util::cli::Args;
use rsir::util::pool::Pool;
use std::time::Instant;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &argv,
        &[
            "bench", "device", "util", "only", "out", "seed", "workers", "ir", "cases",
            "sa-workers", "socket", "port", "cache", "max-queue", "file", "timeout-ms",
            "utils", "grids", "steps", "strategies", "retries", "retry-ms",
        ],
    );
    let mut cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if args.has_flag("version") {
        cmd = "version";
    }
    if let Err(e) = dispatch(cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flow_config(args: &Args) -> flow::FlowConfig {
    let mut cfg = flow::FlowConfig {
        use_pjrt: args.has_flag("pjrt"),
        sa_refine: !args.has_flag("no-sa"),
        ..Default::default()
    };
    cfg.util_limit = args.get_f64("util", cfg.util_limit);
    cfg.sa.seed = args.get_usize("seed", cfg.sa.seed as usize) as u64;
    // Parallel-chains width of the incremental SA lane. A wall-clock
    // knob only: annealing results are identical for any value.
    cfg.sa.workers = args.get_usize("sa-workers", cfg.sa.workers);
    cfg
}

/// Parse a comma-separated CLI list (`--utils 0.6,0.7`), trimming blanks.
fn parse_list<T>(flag: &str, s: &str, f: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| f(t).map_err(|e| anyhow::anyhow!("--{flag}: bad entry '{t}': {e:#}")))
        .collect()
}

/// Effective worker-count override: `--workers N` when given and parseable.
fn workers_cli(args: &Args) -> Option<usize> {
    args.get("workers").and_then(|v| v.parse::<usize>().ok())
}

/// Daemon endpoint from `--socket <path>` or `--port <n>` (exactly one).
fn bind_from_args(args: &Args) -> Result<rsir::server::Bind> {
    match (args.get("socket"), args.get("port")) {
        (Some(path), None) => Ok(rsir::server::Bind::Unix(std::path::PathBuf::from(path))),
        (None, Some(port)) => Ok(rsir::server::Bind::Tcp(
            port.parse()
                .map_err(|_| anyhow::anyhow!("--port must be a number, got '{port}'"))?,
        )),
        _ => bail!("exactly one of --socket <path> or --port <n> is required"),
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    let pool = Pool::from_env(workers_cli(args));
    match cmd {
        "devices" => {
            let mut t = Table::new(&["Name", "Part", "Grid", "Dies", "kLUT", "DSP", "SLL/col"]);
            for name in builtin::BUILTIN_NAMES {
                let d = builtin::by_name(name)?;
                let cap = d.total_capacity();
                t.row(&[
                    d.name.clone(),
                    d.part.clone(),
                    format!("{}x{}", d.cols, d.rows),
                    d.num_dies().to_string(),
                    format!("{:.0}", cap.lut / 1000.0),
                    format!("{:.0}", cap.dsp),
                    d.sll_per_column.to_string(),
                ]);
            }
            t.print();
        }
        "flow" => {
            let bench = args.get_or("bench", "llama2");
            let device = args.get_or("device", "u280");
            let (app, id) = match bench {
                b if b.starts_with("cnn") => ("CNN", b),
                b => (b, b),
            };
            let (row, stats) = report::run_row_timed(app, id, device, &flow_config(args))?;
            report::render_table2(&[row]).print();
            println!("{}", stats.render());
            println!("{}", stats.render_passes());
        }
        "passes" => {
            let mut t = Table::new(&["Name", "Argument", "Description"]);
            for e in registry::passes() {
                t.row(&[
                    e.name.to_string(),
                    e.arg.unwrap_or("").to_string(),
                    e.description.to_string(),
                ]);
            }
            t.print();
            println!();
            let mut t = Table::new(&["Pipeline", "Passes", "Description"]);
            for p in registry::pipelines() {
                t.row(&[
                    p.name.to_string(),
                    registry::build(p.name)?.len().to_string(),
                    p.description.to_string(),
                ]);
            }
            t.print();
            println!("\nrun one with: rsir pipeline <name-or-spec> [--bench id | --ir file.json]");
        }
        "pipeline" => {
            let spec = args.positional.get(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: rsir pipeline <spec> [--bench id | --ir file.json] [--out ir.json] [--drc]"
                )
            })?;
            let pipeline = registry::build(spec)?;
            let mut design = match args.get("ir") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)?;
                    rsir::ir::schema::design_from_json(&rsir::util::json::Json::parse(&text)?)?
                }
                None => report::generate_by_id(args.get_or("bench", "llama2"))?.design,
            };
            let mut ctx = PassContext::new();
            // Interleaved DRC is opt-in here, matching the flow's stage
            // contract: mid-pipeline states may be transiently
            // inconsistent (e.g. between partition and passthrough).
            ctx.drc_after_each = args.has_flag("drc");
            let rep = pipeline.run(&mut design, &mut ctx)?;
            let mut t = Table::new(&["#", "Pass", "Wall", "DRC", "Log lines"]);
            for (i, p) in rep.passes.iter().enumerate() {
                t.row(&[
                    (i + 1).to_string(),
                    p.name.clone(),
                    format!("{:.2?}", p.wall),
                    match p.drc {
                        DrcOutcome::Clean => "clean".to_string(),
                        DrcOutcome::Skipped => "-".to_string(),
                    },
                    p.log.len().to_string(),
                ]);
            }
            t.print();
            println!("{}", rep.render());
            for line in &ctx.log {
                println!("  {line}");
            }
            if let Some(path) = args.get("out") {
                std::fs::write(path, rsir::ir::schema::design_to_json(&design).pretty())?;
                println!("wrote transformed IR to {path}");
            }
        }
        "fuzz" => {
            let cfg = rsir::designs::synthetic::SyntheticConfig::default();
            if args.has_flag("digests") {
                // Pinnable seed digests (see tests/golden/): fuzz failures
                // stay replayable only if seeds regenerate identically.
                let pairs = rsir::testing::fuzz::seed_digests(0..5, &cfg);
                let mut t = Table::new(&["Seed", "Digest"]);
                for (seed, h) in &pairs {
                    t.row(&[seed.to_string(), format!("{h:016x}")]);
                }
                t.print();
                if let Some(path) = args.get("out") {
                    // Golden-file format (`<seed> <hex-digest>` per line):
                    // drop the output straight into
                    // rust/tests/golden/synthetic_digests.txt to pin it.
                    let text: String = pairs
                        .iter()
                        .map(|(s, h)| format!("{s} {h:016x}\n"))
                        .collect();
                    std::fs::write(path, text)?;
                    println!("wrote {path}");
                }
                return Ok(());
            }
            let seed = args.get_usize("seed", 0) as u64;
            if args.has_flag("daemon") {
                // Daemon-equivalence lane: every daemon response byte must
                // match the one-shot CLI's (see server module docs).
                let cases = args.get_usize("cases", 32);
                let t0 = Instant::now();
                let rep = rsir::testing::fuzz::run_daemon(seed, cases, &cfg);
                if rep.is_clean() {
                    println!(
                        "fuzz --daemon: {cases} designs from seed {seed} byte-identical \
                         between daemon and one-shot lanes in {:.2?}",
                        t0.elapsed()
                    );
                    return Ok(());
                }
                for v in &rep.violations {
                    eprintln!("  {v}");
                }
                if let Some(json) = &rep.minimal_json {
                    let out = args.get_or("out", "fuzz_daemon_counterexample.json");
                    std::fs::write(out, json)?;
                    eprintln!("minimal counterexample IR written to {out}");
                }
                bail!(
                    "daemon-equivalence violated ({} violation(s); replay: rsir fuzz \
                     --daemon --seed {seed} --cases {cases})",
                    rep.violations.len()
                );
            }
            if args.has_flag("reflow") {
                // Incremental re-flow lane: byte-identity of memoized
                // flows against from-scratch runs (see testing::oracle).
                let cases = args.get_usize("cases", 16);
                let t0 = Instant::now();
                let rep = rsir::testing::fuzz::run_reflow(seed, cases, &cfg);
                match rep.failure {
                    None => println!(
                        "fuzz --reflow: {cases} designs from seed {seed} re-flowed \
                         byte-identically in {:.2?}",
                        t0.elapsed()
                    ),
                    Some(f) => {
                        let out = args.get_or("out", "fuzz_reflow_counterexample.json");
                        std::fs::write(out, &f.minimal_json)?;
                        eprintln!(
                            "fuzz --reflow: case {} (seed {seed}) violated: {}",
                            f.case,
                            f.violations.join(", ")
                        );
                        eprintln!(
                            "minimal counterexample violates: {}",
                            f.minimal_violations.join(", ")
                        );
                        eprintln!("minimal plan:\n{:#?}", f.minimal_plan);
                        bail!(
                            "re-flow identity violated; minimal counterexample IR written to \
                             {out} (replay: rsir fuzz --reflow --seed {seed} --cases {cases})"
                        );
                    }
                }
                return Ok(());
            }
            if args.has_flag("faults") {
                // Fault-resilience lane: typed-error-or-identical-bytes
                // under an armed fault plan (see testing::faults).
                let cases = args.get_usize("cases", 64);
                let t0 = Instant::now();
                let rep = rsir::testing::fuzz::run_faults(seed, cases, &cfg);
                if rep.is_clean() {
                    println!(
                        "fuzz --faults: {cases} (design, fault-plan) pairs from seed {seed} \
                         resilient in {:.2?} ({} sites covered)",
                        t0.elapsed(),
                        rep.covered.len()
                    );
                    return Ok(());
                }
                for v in &rep.violations {
                    eprintln!("  {v}");
                }
                if let Some(faults) = &rep.minimal_faults {
                    eprintln!("minimal fault plan: {faults}");
                }
                if let Some(json) = &rep.minimal_json {
                    let out = args.get_or("out", "fuzz_faults_counterexample.json");
                    std::fs::write(out, json)?;
                    eprintln!("minimal (design, fault-plan) pair written to {out}");
                }
                bail!(
                    "fault resilience violated ({} violation(s); replay: rsir fuzz \
                     --faults --seed {seed} --cases {cases})",
                    rep.violations.len()
                );
            }
            let cases = args.get_usize("cases", 64);
            let t0 = Instant::now();
            if args.has_flag("verilog") {
                // Verilog round-trip lane: materialized source text →
                // import → pipeline → export → re-import, per case.
                let rep = rsir::testing::fuzz::run_verilog(seed, cases, &cfg);
                match rep.failure {
                    None => println!(
                        "fuzz --verilog: {cases} designs from seed {seed} passed the \
                         round-trip oracle in {:.2?}",
                        t0.elapsed()
                    ),
                    Some(f) => {
                        let out = args.get_or("out", "fuzz_counterexample.v");
                        std::fs::write(out, &f.minimal_source)?;
                        eprintln!(
                            "fuzz --verilog: case {} (seed {seed}) violated: {}",
                            f.case,
                            f.violations.join(", ")
                        );
                        eprintln!(
                            "minimal counterexample violates: {}",
                            f.minimal_violations.join(", ")
                        );
                        eprintln!("minimal plan:\n{:#?}", f.minimal_plan);
                        bail!(
                            "round-trip invariant violated; minimal Verilog source written \
                             to {out} (replay: rsir fuzz --verilog --seed {seed} --cases {cases})"
                        );
                    }
                }
                return Ok(());
            }
            let rep = rsir::testing::fuzz::run(seed, cases, &cfg);
            match rep.failure {
                None => println!(
                    "fuzz: {cases} designs from seed {seed} passed the oracle suite in {:.2?}",
                    t0.elapsed()
                ),
                Some(f) => {
                    let out = args.get_or("out", "fuzz_counterexample.json");
                    std::fs::write(out, &f.minimal_json)?;
                    eprintln!(
                        "fuzz: case {} (seed {seed}) violated: {}",
                        f.case,
                        f.violations.join(", ")
                    );
                    eprintln!(
                        "minimal counterexample violates: {}",
                        f.minimal_violations.join(", ")
                    );
                    eprintln!("minimal plan:\n{:#?}", f.minimal_plan);
                    bail!(
                        "oracle invariant violated; minimal counterexample IR written to {out} \
                         (replay: rsir fuzz --seed {seed} --cases {cases})"
                    );
                }
            }
        }
        "table1" => report::table1().print(),
        "table2" => {
            let t0 = Instant::now();
            let rows = report::table2(args.get("only"), &flow_config(args), &pool)?;
            report::render_table2(&rows).print();
            summary(&rows);
            println!(
                "{} flows on {} workers in {:.2?}",
                rows.len(),
                pool.workers(),
                t0.elapsed()
            );
        }
        "fig12" => {
            let device = args.get_or("device", "vhk158");
            let dev = builtin::by_name(device)?;
            let g = rsir::designs::llama2::generate(&Default::default())?;
            let rows = explore::explore(
                &g.design,
                &dev,
                &explore::default_limits(),
                &flow_config(args),
                &pool,
            )?;
            let mut t = Table::new(&["util_limit", "max_slot_util", "wirelength", "Fmax (MHz)"]);
            for r in &rows {
                t.row(&[
                    format!("{:.2}", r.util_limit),
                    format!("{:.2}", r.max_slot_util),
                    format!("{:.0}", r.wirelength),
                    if r.routable {
                        format!("{:.0}", r.fmax_mhz)
                    } else {
                        "-".into()
                    },
                ]);
            }
            t.print();
        }
        "dse" => {
            let device = args.get_or("device", "u280");
            let dev = builtin::by_name(device)?;
            let g = report::generate_by_id(args.get_or("bench", "llama2"))?;
            let mut cfg = dse::DseConfig {
                base: flow_config(args),
                warm_sa: !args.has_flag("no-warm"),
                ..Default::default()
            };
            if let Some(v) = args.get("utils") {
                cfg.utils = parse_list("utils", v, |t| Ok(t.parse::<f64>()?))?;
            }
            if let Some(v) = args.get("grids") {
                cfg.grids = parse_list("grids", v, |t| Ok(t.parse::<usize>()?))?;
            }
            if let Some(v) = args.get("steps") {
                cfg.sa_steps = parse_list("steps", v, |t| Ok(t.parse::<usize>()?))?;
            }
            if let Some(v) = args.get("strategies") {
                cfg.strategies = parse_list("strategies", v, flow::PipelineStrategy::parse)?;
            }
            let t0 = Instant::now();
            let report = dse::run_dse(&g.design, &dev, &cfg, &pool)?;
            println!("{}", report.render_front());
            println!(
                "{} points on {} workers in {:.2?} (SA warm-start {})",
                report.rows.len(),
                pool.workers(),
                t0.elapsed(),
                if cfg.warm_sa { "on" } else { "off" },
            );
            if let Some(path) = args.get("out") {
                std::fs::write(path, report.to_json().pretty())?;
                println!("wrote {path}");
            }
        }
        "fig13" => {
            let dev = builtin::by_name("u250")?;
            // The worker count doubles as the modeled vendor job-farm
            // width, so Figure 13 defaults to the paper's 8 jobs rather
            // than the machine's parallelism (CLI and env still override).
            let workers = rsir::util::pool::resolve_workers_or(workers_cli(args), 8);
            let model = rsir::eda::SynthTimeModel::default();
            let mut t = Table::new(&["CNN", "Monolithic (s)", "Parallel (s)", "Speedup"]);
            let mut speedups = Vec::new();
            for cols in [4usize, 6, 8, 10, 12] {
                let g = rsir::designs::cnn::generate(&rsir::designs::cnn::CnnConfig {
                    rows: 13,
                    cols,
                })?;
                let mut d = g.design;
                flow::run_hlps(&mut d, &dev, &flow_config(args))?;
                let rep = parallel_synth::run(&d, &dev, workers, &model)?;
                speedups.push(rep.modeled_speedup);
                t.row(&[
                    format!("13x{cols}"),
                    format!("{:.0}", rep.modeled_monolithic_s),
                    format!("{:.0}", rep.modeled_parallel_s),
                    format!("{:.2}x", rep.modeled_speedup),
                ]);
            }
            t.print();
            println!(
                "average speedup: {:.2}x (paper: 2.49x)",
                speedups.iter().sum::<f64>() / speedups.len() as f64
            );
        }
        "import" => {
            let top = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: rsir import <top> <file.v>..."))?;
            let mut sources = Vec::new();
            for f in &args.positional[2..] {
                sources.push(std::fs::read_to_string(f)?);
            }
            let refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
            let design = rsir::plugins::import_design(top, &refs)?;
            let json = rsir::ir::schema::design_to_json(&design).pretty();
            match args.get("out") {
                Some(path) => std::fs::write(path, json)?,
                None => println!("{json}"),
            }
        }
        "export" => {
            let ir = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: rsir export <ir.json> <outdir>"))?;
            let outdir = args.positional.get(2).map(|s| s.as_str()).unwrap_or("out");
            let text = std::fs::read_to_string(ir)?;
            let design =
                rsir::ir::schema::design_from_json(&rsir::util::json::Json::parse(&text)?)?;
            let bundle = rsir::plugins::export(&design)?;
            bundle.write_to_dir(std::path::Path::new(outdir))?;
            println!("wrote {} files to {outdir}", bundle.files.len());
        }
        "serve" => {
            let mut cfg = rsir::server::ServeConfig::new(bind_from_args(args)?);
            if let Some(w) = workers_cli(args) {
                cfg.workers = w;
            }
            cfg.cache_cap = args.get_usize("cache", cfg.cache_cap);
            cfg.max_queue = args.get_usize("max-queue", cfg.max_queue);
            cfg.quiet = args.has_flag("quiet");
            rsir::server::serve(cfg)?;
        }
        "submit" => {
            let text = match args.get("file") {
                Some(path) => std::fs::read_to_string(path)?,
                None => {
                    let mut buf = String::new();
                    std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
                    buf
                }
            };
            let lines: Vec<String> = text.lines().map(str::to_string).collect();
            let responses = if args.has_flag("local") {
                // The one-shot lane: same requests, same bytes, no daemon.
                rsir::server::client::run_batch_local(&lines)
            } else {
                let timeout = std::time::Duration::from_millis(
                    args.get_usize("timeout-ms", 300_000) as u64,
                );
                let mut policy = rsir::server::client::RetryPolicy::default();
                policy.attempts = args.get_usize("retries", policy.attempts as usize) as u32;
                let base_ms =
                    args.get_usize("retry-ms", policy.base_delay.as_millis() as usize) as u64;
                policy.base_delay = std::time::Duration::from_millis(base_ms);
                policy.max_delay = std::time::Duration::from_millis(base_ms.saturating_mul(16));
                rsir::server::client::run_batch_remote_with(
                    &bind_from_args(args)?,
                    &lines,
                    timeout,
                    &policy,
                )?
            };
            for line in responses {
                println!("{line}");
            }
        }
        "version" => {
            println!(
                "rsir {} (daemon protocol {})",
                rsir::server::protocol::VERSION,
                rsir::server::protocol::PROTOCOL_VERSION
            );
        }
        "help" | "--help" => {
            println!("rsir — RapidStream IR (ICCAD'24 reproduction)");
            println!("commands: devices flow passes pipeline table1 table2 fig12 fig13 dse import export fuzz serve submit version");
            println!("dse: `rsir dse --utils 0.6,0.7 --grids 1,2 --steps 60,120 --strategies full,dies` sweeps the knob space and prints the Pareto front");
            println!("global: --workers N (or RSIR_WORKERS) sizes the evaluation pool");
            println!("SA: --sa-workers N parallelizes annealing chains (same results for any N)");
            println!("pass registry: `rsir passes` lists it; `rsir pipeline <spec>` runs one");
            println!("fuzzing: `rsir fuzz --seed N --cases M` replays/shrinks oracle failures");
            println!("         `rsir fuzz --reflow` checks memoized re-flows stay byte-identical");
            println!("         `rsir fuzz --faults` arms seeded fault plans against a live daemon");
            println!("daemon: `rsir serve --socket /tmp/rsir.sock` + `rsir submit --socket ... --file reqs.jsonl`");
        }
        other => bail!("unknown command '{other}' (try 'rsir help')"),
    }
    Ok(())
}

fn summary(rows: &[report::Table2Row]) {
    let imps: Vec<f64> = rows.iter().filter_map(|r| r.improvement()).collect();
    if !imps.is_empty() {
        println!(
            "average improvement (excluding originally-unroutable): +{:.0}% over {} designs",
            imps.iter().sum::<f64>() / imps.len() as f64,
            imps.len()
        );
    }
    let unroutable = rows.iter().filter(|r| r.original_mhz.is_none()).count();
    if unroutable > 0 {
        println!("{unroutable} designs unroutable with the vendor-only flow (\"-\")");
    }
}

//! Utility plugins (§3.2): importers, analyzers, and exporters bridging
//! the abstract IR and concrete design formats / EDA tools.

pub mod exporter;
pub mod hls_report;
pub mod iface_rules;
pub mod importer;
pub mod platform;
pub mod pragma;
pub mod xci;
pub mod xo;

pub use exporter::{export, ExportBundle};
pub use iface_rules::RuleSet;
pub use importer::{import_design, import_verilog, import_vhdl};

//! Interface rules (§3.2 / Figure 11): regex-based rules that attach
//! interface information to modules whose sources carry none — the
//! mechanism that onboards Dynamatic, Catapult HLS and Intel HLS RTL with
//! a handful of rules each (Table 1).
//!
//! ```text
//! add_reset(module=".*", port="rst|reset", active="high")
//! add_handshake(module=top, pattern="{bundle}_{role}",
//!               role={ready:"ready", valid:"valid", data:"in|out"})
//! ```

use crate::ir::core::*;
use anyhow::{anyhow, Result};
use regex::Regex;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
enum Rule {
    Clock {
        module: String,
        port: String,
    },
    Reset {
        module: String,
        port: String,
        active_high: bool,
    },
    Handshake {
        module: String,
        /// Port-name pattern with `{bundle}` and `{role}` placeholders.
        pattern: String,
        role_valid: String,
        role_ready: String,
        role_data: String,
    },
    Feedforward {
        module: String,
        port: String,
    },
    NonPipeline {
        module: String,
        port: String,
    },
}

/// A set of interface rules, applied to a whole design.
#[derive(Debug, Clone, Default)]
pub struct RuleSet {
    rules: Vec<Rule>,
}

impl RuleSet {
    pub fn new() -> RuleSet {
        RuleSet::default()
    }

    pub fn len(&self) -> usize {
        self.rules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn add_clock(mut self, module: &str, port: &str) -> Self {
        self.rules.push(Rule::Clock {
            module: module.into(),
            port: port.into(),
        });
        self
    }

    pub fn add_reset(mut self, module: &str, port: &str, active: &str) -> Self {
        self.rules.push(Rule::Reset {
            module: module.into(),
            port: port.into(),
            active_high: active != "low",
        });
        self
    }

    /// `pattern` uses `{bundle}` / `{role}` placeholders; `roles` maps the
    /// role part onto valid/ready/data regexes.
    pub fn add_handshake(
        mut self,
        module: &str,
        pattern: &str,
        valid: &str,
        ready: &str,
        data: &str,
    ) -> Self {
        self.rules.push(Rule::Handshake {
            module: module.into(),
            pattern: pattern.into(),
            role_valid: valid.into(),
            role_ready: ready.into(),
            role_data: data.into(),
        });
        self
    }

    pub fn add_feedforward(mut self, module: &str, port: &str) -> Self {
        self.rules.push(Rule::Feedforward {
            module: module.into(),
            port: port.into(),
        });
        self
    }

    pub fn add_nonpipeline(mut self, module: &str, port: &str) -> Self {
        self.rules.push(Rule::NonPipeline {
            module: module.into(),
            port: port.into(),
        });
        self
    }

    /// Apply all rules to every matching module of the design. Ports
    /// already covered by an interface are never re-covered. Returns the
    /// number of interfaces created.
    pub fn apply(&self, design: &mut Design) -> Result<usize> {
        let mut created = 0;
        let names: Vec<String> = design.modules.keys().cloned().collect();
        for rule in &self.rules {
            let module_re = full_match(rule_module(rule))?;
            for name in &names {
                if !module_re.is_match(name) {
                    continue;
                }
                let m = design.module_mut(name).unwrap();
                created += apply_rule(rule, m)?;
            }
        }
        Ok(created)
    }
}

fn rule_module(r: &Rule) -> &str {
    match r {
        Rule::Clock { module, .. }
        | Rule::Reset { module, .. }
        | Rule::Handshake { module, .. }
        | Rule::Feedforward { module, .. }
        | Rule::NonPipeline { module, .. } => module,
    }
}

fn full_match(pat: &str) -> Result<Regex> {
    Regex::new(&format!("^(?:{pat})$")).map_err(|e| anyhow!("bad regex '{pat}': {e}"))
}

fn apply_rule(rule: &Rule, m: &mut Module) -> Result<usize> {
    let mut created = 0;
    match rule {
        Rule::Clock { port, .. } => {
            let re = full_match(port)?;
            let hits: Vec<String> = uncovered(m)
                .into_iter()
                .filter(|p| re.is_match(p))
                .collect();
            for p in hits {
                m.interfaces.push(Interface::Clock { port: p });
                created += 1;
            }
        }
        Rule::Reset {
            port, active_high, ..
        } => {
            let re = full_match(port)?;
            let hits: Vec<String> = uncovered(m)
                .into_iter()
                .filter(|p| re.is_match(p))
                .collect();
            for p in hits {
                m.interfaces.push(Interface::Reset {
                    port: p,
                    active_high: *active_high,
                });
                created += 1;
            }
        }
        Rule::Feedforward { port, .. } | Rule::NonPipeline { port, .. } => {
            let re = full_match(port)?;
            let hits: Vec<String> = uncovered(m)
                .into_iter()
                .filter(|p| re.is_match(p))
                .collect();
            for p in hits {
                m.interfaces.push(match rule {
                    Rule::Feedforward { .. } => Interface::Feedforward {
                        name: p.clone(),
                        ports: vec![p],
                    },
                    _ => Interface::NonPipeline {
                        name: p.clone(),
                        ports: vec![p],
                    },
                });
                created += 1;
            }
        }
        Rule::Handshake {
            pattern,
            role_valid,
            role_ready,
            role_data,
            ..
        } => {
            created += apply_handshake_pattern(m, pattern, role_valid, role_ready, role_data)?;
        }
    }
    Ok(created)
}

fn uncovered(m: &Module) -> Vec<String> {
    m.uncovered_ports()
        .iter()
        .map(|p| p.name.clone())
        .collect()
}

/// Shared with the pragma plugin: group ports by `{bundle}` and classify
/// the `{role}` part, then emit one handshake (or feedforward fallback)
/// interface per bundle.
///
/// Two-pass matching handles separator-free patterns like Figure 9's
/// `m_axi_{bundle}{role}`: valid/ready roles are anchored first (their
/// regexes are specific, so they uniquely determine the bundle names),
/// then data ports prefer the longest already-known bundle prefix
/// (`m_axi_AWADDR` → bundle `AW`, not `A`).
pub fn apply_handshake_pattern(
    m: &mut Module,
    pattern: &str,
    role_valid: &str,
    role_ready: &str,
    role_data: &str,
) -> Result<usize> {
    let make_re = |role_pat: &str| -> Result<Regex> {
        let src = regex::escape(pattern)
            .replace(r"\{bundle\}", "(?P<bundle>.*?)")
            .replace(r"\{role\}", &format!("(?P<role>(?:{role_pat}))"));
        Regex::new(&format!("^{src}$")).map_err(|e| anyhow!("bad pattern '{pattern}': {e}"))
    };
    let bundle_re = |bundle: &str, role_pat: &str| -> Result<Regex> {
        let src = regex::escape(pattern)
            .replace(r"\{bundle\}", &regex::escape(bundle))
            .replace(r"\{role\}", &format!("(?:{role_pat})"));
        Regex::new(&format!("^{src}$")).map_err(|e| anyhow!("bad pattern '{pattern}': {e}"))
    };
    let re_valid = make_re(role_valid)?;
    let re_ready = make_re(role_ready)?;
    let re_data = make_re(role_data)?;

    #[derive(Default)]
    struct Bundle {
        data: Vec<String>,
        valid: Option<String>,
        ready: Option<String>,
    }
    let mut bundles: BTreeMap<String, Bundle> = BTreeMap::new();
    let ports = uncovered(m);

    // Pass 1: valid/ready define the bundles.
    let mut rest: Vec<String> = Vec::new();
    for pname in ports {
        let vb = re_valid
            .captures(&pname)
            .map(|c| c.name("bundle").map(|b| b.as_str()).unwrap_or("").to_string());
        let rb = re_ready
            .captures(&pname)
            .map(|c| c.name("bundle").map(|b| b.as_str()).unwrap_or("").to_string());
        if let Some(bundle) = vb {
            bundles.entry(bundle).or_default().valid = Some(pname);
        } else if let Some(bundle) = rb {
            bundles.entry(bundle).or_default().ready = Some(pname);
        } else {
            rest.push(pname);
        }
    }
    // Pass 2: data ports prefer the longest known bundle.
    let mut known: Vec<String> = bundles.keys().cloned().collect();
    known.sort_by_key(|b| std::cmp::Reverse(b.len()));
    'port: for pname in rest {
        for b in &known {
            if bundle_re(b, role_data)?.is_match(&pname) {
                bundles.get_mut(b).unwrap().data.push(pname);
                continue 'port;
            }
        }
        if let Some(caps) = re_data.captures(&pname) {
            let bundle = caps
                .name("bundle")
                .map(|b| b.as_str().to_string())
                .unwrap_or_default();
            bundles.entry(bundle).or_default().data.push(pname);
        }
    }

    let mut created = 0;
    for (bname, b) in bundles {
        // Unique interface name within the module (pragma fallback
        // bundles may otherwise collide on "hs").
        let mut bname = if bname.is_empty() { "hs".to_string() } else { bname };
        while m.interfaces.iter().any(|i| i.name() == bname) {
            bname.push('_');
        }
        match (&b.valid, &b.ready) {
            (Some(v), Some(r)) => {
                m.interfaces.push(Interface::Handshake {
                    name: bname,
                    data: b.data,
                    valid: v.clone(),
                    ready: r.clone(),
                    clk: None,
                });
                created += 1;
            }
            _ if !b.data.is_empty() => {
                // Data without a full handshake: feedforward bundle (the
                // stray valid/ready ports, if any, ride along so they do
                // not end up uncovered).
                let mut ports = b.data;
                ports.extend(b.valid);
                ports.extend(b.ready);
                m.interfaces.push(Interface::Feedforward {
                    name: bname,
                    ports,
                });
                created += 1;
            }
            _ => {}
        }
    }
    Ok(created)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::LeafBuilder;

    /// Dynamatic-style elastic module: consistent `{bundle}_{role}` names.
    fn dynamatic_module() -> Module {
        LeafBuilder::verilog_stub("fir")
            .port("clk", Dir::In, 1)
            .port("rst", Dir::In, 1)
            .port("in0_data", Dir::In, 32)
            .port("in0_valid", Dir::In, 1)
            .port("in0_ready", Dir::Out, 1)
            .port("out0_data", Dir::Out, 32)
            .port("out0_valid", Dir::Out, 1)
            .port("out0_ready", Dir::In, 1)
            .build()
    }

    fn dynamatic_rules() -> RuleSet {
        RuleSet::new()
            .add_clock(".*", "clk|clock")
            .add_reset(".*", "rst|reset", "high")
            .add_handshake(".*", "{bundle}_{role}", "valid", "ready", "data|in|out")
    }

    #[test]
    fn dynamatic_handshakes_detected() {
        let mut d = Design::new("fir");
        d.add(dynamatic_module());
        let n = dynamatic_rules().apply(&mut d).unwrap();
        assert_eq!(n, 4); // clk, rst, in0, out0
        let m = d.module("fir").unwrap();
        assert_eq!(m.interface_of("in0_data").unwrap().kind(), "handshake");
        assert_eq!(m.interface_of("out0_ready").unwrap().kind(), "handshake");
        assert_eq!(m.interface_of("clk").unwrap().kind(), "clock");
        assert!(m.uncovered_ports().is_empty());
    }

    #[test]
    fn module_scoping_respected() {
        let mut d = Design::new("top");
        d.add(dynamatic_module());
        let mut other = dynamatic_module();
        other.name = "top".into();
        d.add(other);
        let rules = RuleSet::new().add_handshake("fir", "{bundle}_{role}", "valid", "ready", ".*");
        rules.apply(&mut d).unwrap();
        assert!(d.module("fir").unwrap().interface_of("in0_data").is_some());
        assert!(d.module("top").unwrap().interface_of("in0_data").is_none());
    }

    #[test]
    fn existing_interfaces_not_overwritten() {
        let mut d = Design::new("fir");
        let mut m = dynamatic_module();
        m.interfaces.push(Interface::NonPipeline {
            name: "pin".into(),
            ports: vec!["in0_data".into(), "in0_valid".into(), "in0_ready".into()],
        });
        d.add(m);
        dynamatic_rules().apply(&mut d).unwrap();
        let m = d.module("fir").unwrap();
        assert_eq!(m.interface_of("in0_data").unwrap().name(), "pin");
        // out0 still picked up as handshake.
        assert_eq!(m.interface_of("out0_data").unwrap().kind(), "handshake");
    }

    #[test]
    fn partial_bundle_becomes_feedforward() {
        let mut d = Design::new("m");
        d.add(
            LeafBuilder::verilog_stub("m")
                .port("cfg_data", Dir::In, 16)
                .build(),
        );
        RuleSet::new()
            .add_handshake(".*", "{bundle}_{role}", "valid", "ready", "data")
            .apply(&mut d)
            .unwrap();
        assert_eq!(
            d.module("m").unwrap().interface_of("cfg_data").unwrap().kind(),
            "feedforward"
        );
    }

    #[test]
    fn axi_style_pattern() {
        // Fig 9: pattern=m_axi_{bundle}{role}, VALID/READY suffixes.
        let mut d = Design::new("InputLoader");
        d.add(
            LeafBuilder::verilog_stub("InputLoader")
                .port("m_axi_AWVALID", Dir::Out, 1)
                .port("m_axi_AWREADY", Dir::In, 1)
                .port("m_axi_AWADDR", Dir::Out, 64)
                .port("m_axi_WVALID", Dir::Out, 1)
                .port("m_axi_WREADY", Dir::In, 1)
                .port("m_axi_WDATA", Dir::Out, 512)
                .build(),
        );
        RuleSet::new()
            .add_handshake(".*", "m_axi_{bundle}{role}", "VALID", "READY", ".*")
            .apply(&mut d)
            .unwrap();
        let m = d.module("InputLoader").unwrap();
        let aw = m.interface_of("m_axi_AWVALID").unwrap();
        assert_eq!(aw.kind(), "handshake");
        assert!(aw.ports().contains(&"m_axi_AWADDR"));
        assert!(!aw.ports().contains(&"m_axi_WDATA"));
        assert!(m.interface_of("m_axi_WDATA").is_some());
    }

    #[test]
    fn bad_regex_reported() {
        let mut d = Design::new("x");
        d.add(LeafBuilder::verilog_stub("x").build());
        assert!(RuleSet::new().add_clock(".*", "(").apply(&mut d).is_err());
    }
}

//! Vitis-HLS report importer (§3.2, "Vitis HLS provides interface
//! information in report files"). Our surrogate consumes the JSON shape
//! the benchmark generators fabricate — the same content a
//! `csynth.xml` / `*_csynth.rpt` pair carries:
//!
//! ```json
//! {
//!   "modules": {
//!     "Layer1": {
//!       "resource": {"LUT": 52000, "FF": 61000, "BRAM": 48, "DSP": 256, "URAM": 8},
//!       "timing": {"internal_ns": 3.1},
//!       "interfaces": [
//!         {"type": "handshake", "name": "i",
//!          "data": ["i"], "valid": "i_vld", "ready": "i_rdy"}
//!       ]
//!     }
//!   }
//! }
//! ```

use crate::ir::core::*;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Apply an HLS report to a design: resource/timing metadata and missing
/// interface info for every module the report mentions. Returns the
/// number of modules annotated.
pub fn apply_report(design: &mut Design, report: &str) -> Result<usize> {
    let j = Json::parse(report).map_err(|e| anyhow!("hls report: {e}"))?;
    let mods = j
        .at("modules")
        .and_then(|m| m.as_obj())
        .ok_or_else(|| anyhow!("hls report missing 'modules'"))?;
    let mut annotated = 0;
    for (name, info) in mods.iter() {
        let Some(m) = design.module_mut(name) else {
            continue;
        };
        if let Some(r) = info.at("resource") {
            m.metadata.insert("resource", r.clone());
        }
        if let Some(t) = info.at("timing") {
            m.metadata.insert("timing", t.clone());
        }
        if let Some(ifaces) = info.at("interfaces").and_then(|i| i.as_arr()) {
            for ij in ifaces {
                let kind = ij.at("type").and_then(|t| t.as_str()).unwrap_or("");
                match kind {
                    "handshake" => {
                        let valid = ij
                            .at("valid")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| anyhow!("handshake missing valid"))?;
                        if m.interface_of(valid).is_some() {
                            continue;
                        }
                        m.interfaces.push(Interface::Handshake {
                            name: ij
                                .at("name")
                                .and_then(|n| n.as_str())
                                .unwrap_or("hs")
                                .to_string(),
                            data: ij
                                .at("data")
                                .and_then(|d| d.as_arr())
                                .map(|a| {
                                    a.iter()
                                        .filter_map(|v| v.as_str().map(String::from))
                                        .collect()
                                })
                                .unwrap_or_default(),
                            valid: valid.to_string(),
                            ready: ij
                                .at("ready")
                                .and_then(|r| r.as_str())
                                .ok_or_else(|| anyhow!("handshake missing ready"))?
                                .to_string(),
                            clk: None,
                        });
                    }
                    "clock" => {
                        if let Some(p) = ij.at("port").and_then(|p| p.as_str()) {
                            if m.interface_of(p).is_none() {
                                m.interfaces.push(Interface::Clock { port: p.into() });
                            }
                        }
                    }
                    "reset" => {
                        if let Some(p) = ij.at("port").and_then(|p| p.as_str()) {
                            if m.interface_of(p).is_none() {
                                m.interfaces.push(Interface::Reset {
                                    port: p.into(),
                                    active_high: ij
                                        .at("active_high")
                                        .and_then(|a| a.as_bool())
                                        .unwrap_or(true),
                                });
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
        annotated += 1;
    }
    Ok(annotated)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::LeafBuilder;

    #[test]
    fn report_annotates_matching_modules() {
        let mut d = Design::new("L1");
        d.add(
            LeafBuilder::verilog_stub("L1")
                .port("i", Dir::In, 64)
                .port("i_vld", Dir::In, 1)
                .port("i_rdy", Dir::Out, 1)
                .build(),
        );
        let report = r#"{
          "modules": {
            "L1": {
              "resource": {"LUT": 52000, "FF": 61000, "BRAM": 48, "DSP": 256, "URAM": 8},
              "timing": {"internal_ns": 3.1},
              "interfaces": [
                {"type": "handshake", "name": "i", "data": ["i"],
                 "valid": "i_vld", "ready": "i_rdy"}
              ]
            },
            "NotInDesign": {"resource": {"LUT": 1}}
          }
        }"#;
        let n = apply_report(&mut d, report).unwrap();
        assert_eq!(n, 1);
        let m = d.module("L1").unwrap();
        assert_eq!(
            crate::ir::builder::module_resources(m).unwrap().dsp,
            256.0
        );
        assert_eq!(m.interface_of("i").unwrap().kind(), "handshake");
        assert_eq!(
            m.metadata
                .get("timing")
                .and_then(|t| t.at("internal_ns"))
                .and_then(|v| v.as_f64()),
            Some(3.1)
        );
    }

    #[test]
    fn existing_interfaces_kept() {
        let mut d = Design::new("L1");
        d.add(
            LeafBuilder::verilog_stub("L1")
                .handshake("i", Dir::In, 64)
                .build(),
        );
        let report = r#"{"modules": {"L1": {"interfaces": [
          {"type": "handshake", "name": "dup", "data": ["i"],
           "valid": "i_vld", "ready": "i_rdy"}]}}}"#;
        apply_report(&mut d, report).unwrap();
        let m = d.module("L1").unwrap();
        assert_eq!(m.interfaces.len(), 1);
        assert_eq!(m.interface_of("i").unwrap().name(), "i");
    }

    #[test]
    fn bad_report_rejected() {
        let mut d = Design::new("X");
        assert!(apply_report(&mut d, "oops").is_err());
        assert!(apply_report(&mut d, "{}").is_err());
    }
}

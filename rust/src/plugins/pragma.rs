//! Source-comment pragmas (§3.2 / Figure 9): single-line comments inside
//! a Verilog module that declare its interfaces, e.g.
//!
//! ```verilog
//! // pragma handshake pattern=m_axi_{bundle}{role} \
//! //        role.valid=VALID role.ready=READY role.data=.*
//! // pragma clock port=ap_clk
//! // pragma reset port=ap_rst_n active=low
//! // pragma feedforward port=scalar_.*
//! ```
//!
//! Line continuations with a trailing backslash are supported; key=value
//! tokens are whitespace-separated.

use crate::ir::core::*;
use crate::plugins::iface_rules::apply_handshake_pattern;
use anyhow::{anyhow, Result};
use regex::Regex;
use std::collections::BTreeMap;

/// One parsed pragma: kind + key/value arguments.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    pub kind: String,
    pub args: BTreeMap<String, String>,
}

/// Extract `// pragma ...` comments (with backslash continuations).
pub fn extract_pragmas(source: &str) -> Vec<Pragma> {
    let mut out = Vec::new();
    let mut lines = source.lines().peekable();
    while let Some(line) = lines.next() {
        let t = line.trim_start();
        let Some(body) = t.strip_prefix("//") else {
            continue;
        };
        let body = body.trim_start();
        let Some(rest) = body.strip_prefix("pragma ") else {
            continue;
        };
        let mut text = rest.trim().to_string();
        // Continuation: trailing backslash pulls in following comment lines.
        while text.ends_with('\\') {
            text.pop();
            match lines.peek() {
                Some(next) => {
                    let nt = next.trim_start();
                    if let Some(cb) = nt.strip_prefix("//") {
                        text.push(' ');
                        text.push_str(cb.trim());
                        lines.next();
                    } else {
                        break;
                    }
                }
                None => break,
            }
        }
        let mut parts = text.split_whitespace();
        let Some(kind) = parts.next() else { continue };
        let mut args = BTreeMap::new();
        for tok in parts {
            if let Some((k, v)) = tok.split_once('=') {
                args.insert(k.to_string(), v.to_string());
            }
        }
        out.push(Pragma {
            kind: kind.to_string(),
            args,
        });
    }
    out
}

/// Apply the pragmas found in `source` to module `m` (ports must already
/// be imported). Unknown pragma kinds are ignored (other tools may own
/// them); malformed known pragmas error.
pub fn apply_pragmas(m: &mut Module, source: &str) -> Result<usize> {
    let mut created = 0;
    for p in extract_pragmas(source) {
        // `module=` scopes a pragma to one module of a multi-module file
        // (the exporter concatenates leaf sources into design_leaves.v).
        if let Some(scope) = p.args.get("module") {
            if scope != &m.name {
                continue;
            }
        }
        match p.kind.as_str() {
            "clock" => {
                let port = req(&p, "port")?;
                for pn in match_ports(m, port)? {
                    m.interfaces.push(Interface::Clock { port: pn });
                    created += 1;
                }
            }
            "reset" => {
                let port = req(&p, "port")?;
                let active_high = p.args.get("active").map(|a| a != "low").unwrap_or(true);
                for pn in match_ports(m, port)? {
                    m.interfaces.push(Interface::Reset {
                        port: pn,
                        active_high,
                    });
                    created += 1;
                }
            }
            "feedforward" => {
                let port = req(&p, "port")?;
                for pn in match_ports(m, port)? {
                    m.interfaces.push(Interface::Feedforward {
                        name: pn.clone(),
                        ports: vec![pn],
                    });
                    created += 1;
                }
            }
            "nonpipeline" => {
                let port = req(&p, "port")?;
                for pn in match_ports(m, port)? {
                    m.interfaces.push(Interface::NonPipeline {
                        name: pn.clone(),
                        ports: vec![pn],
                    });
                    created += 1;
                }
            }
            "handshake" => {
                let pattern = req(&p, "pattern")?;
                let valid = p.args.get("role.valid").map(|s| s.as_str()).unwrap_or("valid");
                let ready = p.args.get("role.ready").map(|s| s.as_str()).unwrap_or("ready");
                let data = p.args.get("role.data").map(|s| s.as_str()).unwrap_or(".*");
                created += apply_handshake_pattern(m, pattern, valid, ready, data)?;
            }
            _ => {}
        }
    }
    Ok(created)
}

/// Emit `// pragma ...` comment lines that reconstruct `m`'s interfaces
/// on re-import — the inverse of [`apply_pragmas`]. Every line carries a
/// `module=` scope so concatenated multi-module files don't cross-apply.
///
/// Exact-port pragmas (clock/reset/nonpipeline/feedforward) come first;
/// handshake bundles are folded into one trailing pattern pragma relying
/// on the repo-wide `_vld`/`_rdy` suffix convention. Because pragma
/// application only ever claims *uncovered* ports, this ordering keeps
/// the broad handshake pattern from swallowing exactly-named ports.
pub fn pragma_comments(m: &Module) -> String {
    let mut lines: Vec<String> = Vec::new();
    let scope = format!("module={}", m.name);
    let mut has_handshake = false;
    for iface in &m.interfaces {
        match iface {
            Interface::Clock { port } => {
                lines.push(format!("// pragma clock port={} {scope}", regex::escape(port)));
            }
            Interface::Reset { port, active_high } => lines.push(format!(
                "// pragma reset port={} active={} {scope}",
                regex::escape(port),
                if *active_high { "high" } else { "low" }
            )),
            Interface::NonPipeline { ports, .. } => {
                for p in ports {
                    lines.push(format!(
                        "// pragma nonpipeline port={} {scope}",
                        regex::escape(p)
                    ));
                }
            }
            Interface::Feedforward { ports, .. } => {
                for p in ports {
                    lines.push(format!(
                        "// pragma feedforward port={} {scope}",
                        regex::escape(p)
                    ));
                }
            }
            Interface::Handshake { .. } => has_handshake = true,
        }
    }
    if has_handshake {
        lines.push(format!(
            "// pragma handshake pattern={{bundle}}{{role}} \
             role.valid=_vld role.ready=_rdy role.data=.* {scope}"
        ));
    }
    if lines.is_empty() {
        String::new()
    } else {
        lines.join("\n") + "\n"
    }
}

fn req<'a>(p: &'a Pragma, key: &str) -> Result<&'a str> {
    p.args
        .get(key)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("pragma '{}' missing '{key}'", p.kind))
}

fn match_ports(m: &Module, pattern: &str) -> Result<Vec<String>> {
    let re = Regex::new(&format!("^(?:{pattern})$"))
        .map_err(|e| anyhow!("bad pragma regex '{pattern}': {e}"))?;
    Ok(m.uncovered_ports()
        .iter()
        .filter(|p| re.is_match(&p.name))
        .map(|p| p.name.clone())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::LeafBuilder;

    const FIG9: &str = r#"
module InputLoader (
  output wire m_axi_AWVALID, input wire m_axi_AWREADY,
  output wire m_axi_WVALID, input wire m_axi_WREADY,
  output wire [63:0] m_axi_AWADDR
);
// pragma handshake pattern=m_axi_{bundle}{role} \
//        role.valid=VALID role.ready=READY role.data=.*
// pragma clock port=ap_clk
endmodule
"#;

    #[test]
    fn extracts_with_continuation() {
        let ps = extract_pragmas(FIG9);
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].kind, "handshake");
        assert_eq!(ps[0].args["role.valid"], "VALID");
        assert_eq!(ps[0].args["pattern"], "m_axi_{bundle}{role}");
        assert_eq!(ps[1].kind, "clock");
    }

    #[test]
    fn fig9_example_applies() {
        let mut m = LeafBuilder::verilog_stub("InputLoader")
            .port("m_axi_AWVALID", Dir::Out, 1)
            .port("m_axi_AWREADY", Dir::In, 1)
            .port("m_axi_AWADDR", Dir::Out, 64)
            .port("m_axi_WVALID", Dir::Out, 1)
            .port("m_axi_WREADY", Dir::In, 1)
            .build();
        let n = apply_pragmas(&mut m, FIG9).unwrap();
        assert_eq!(n, 2); // AW + W bundles (no ap_clk port present)
        assert_eq!(m.interface_of("m_axi_AWADDR").unwrap().kind(), "handshake");
        assert!(m.uncovered_ports().is_empty());
    }

    #[test]
    fn reset_active_low() {
        let mut m = LeafBuilder::verilog_stub("M")
            .port("ap_rst_n", Dir::In, 1)
            .build();
        apply_pragmas(&mut m, "// pragma reset port=ap_rst_n active=low\nmodule M(); endmodule")
            .unwrap();
        assert!(matches!(
            m.interfaces[0],
            Interface::Reset {
                active_high: false,
                ..
            }
        ));
    }

    #[test]
    fn unknown_pragmas_ignored() {
        let mut m = LeafBuilder::verilog_stub("M").build();
        let n = apply_pragmas(&mut m, "// pragma synthesis_off foo=bar").unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn malformed_known_pragma_errors() {
        let mut m = LeafBuilder::verilog_stub("M").build();
        assert!(apply_pragmas(&mut m, "// pragma clock").is_err());
    }

    #[test]
    fn non_pragma_comments_skipped() {
        assert!(extract_pragmas("// just a comment\n/* pragma x */").is_empty());
    }

    #[test]
    fn module_scope_limits_application() {
        let src = "// pragma clock port=clk module=A\n// pragma clock port=clk module=B\n";
        let mut a = LeafBuilder::verilog_stub("A").port("clk", Dir::In, 1).build();
        let mut c = LeafBuilder::verilog_stub("C").port("clk", Dir::In, 1).build();
        assert_eq!(apply_pragmas(&mut a, src).unwrap(), 1);
        assert_eq!(apply_pragmas(&mut c, src).unwrap(), 0);
    }

    #[test]
    fn pragma_comments_roundtrip_interfaces() {
        let mut m = LeafBuilder::verilog_stub("M")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .port("b0", Dir::Out, 32)
            .port("b0_vld", Dir::Out, 1)
            .port("b0_rdy", Dir::In, 1)
            .port("b1", Dir::In, 16)
            .port("cfg", Dir::In, 8)
            .build();
        m.interfaces.push(Interface::Clock {
            port: "ap_clk".into(),
        });
        m.interfaces.push(Interface::Reset {
            port: "ap_rst_n".into(),
            active_high: false,
        });
        m.interfaces.push(Interface::Handshake {
            name: "b0".into(),
            data: vec!["b0".into()],
            valid: "b0_vld".into(),
            ready: "b0_rdy".into(),
            clk: Some("ap_clk".into()),
        });
        m.interfaces.push(Interface::Feedforward {
            name: "b1".into(),
            ports: vec!["b1".into()],
        });
        m.interfaces.push(Interface::NonPipeline {
            name: "cfg".into(),
            ports: vec!["cfg".into()],
        });
        let text = pragma_comments(&m);
        // Re-apply onto a bare copy of the module: every port must end up
        // covered by an interface of the same kind.
        let mut fresh = m.clone();
        fresh.interfaces.clear();
        apply_pragmas(&mut fresh, &text).unwrap();
        assert!(fresh.uncovered_ports().is_empty(), "pragmas: {text}");
        for (port, kind) in [
            ("ap_clk", "clock"),
            ("ap_rst_n", "reset"),
            ("b0", "handshake"),
            ("b0_vld", "handshake"),
            ("b1", "feedforward"),
            ("cfg", "nonpipeline"),
        ] {
            assert_eq!(
                fresh.interface_of(port).map(|i| i.kind()),
                Some(kind),
                "port {port}"
            );
        }
        // Scoped: the same text does nothing to a differently-named module.
        let mut other = m.clone();
        other.name = "Other".into();
        other.interfaces.clear();
        assert_eq!(apply_pragmas(&mut other, &text).unwrap(), 0);
    }

    #[test]
    fn pragma_comments_empty_without_interfaces() {
        let m = LeafBuilder::verilog_stub("M").port("a", Dir::In, 1).build();
        assert_eq!(pragma_comments(&m), "");
    }
}

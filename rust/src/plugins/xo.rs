//! Vitis Xilinx Object (XO) container — JSON-manifest surrogate.
//!
//! A real .xo is a zip holding a kernel's RTL plus kernel.xml describing
//! its AXI interfaces. The KNN benchmark (§4.4) is ingested this way:
//! "RIR directly ingests the Vitis-packed Xilinx Object (XO) files for
//! optimization and outputs the optimized design in the same format,
//! acting as a transparent plugin to the Vitis framework." Our manifest:
//!
//! ```json
//! { "kernel": "krnl_knn", "sources": ["<verilog>"],
//!   "top": "krnl_knn", "interfaces": {...iface rules applied after...} }
//! ```

use crate::ir::core::*;
use crate::util::json::{Json, JsonObj};
use anyhow::{anyhow, Result};

/// Import an XO manifest: every contained Verilog module becomes a leaf;
/// the kernel top is returned first. The manifest itself is embedded in
/// the kernel-top module so the exporter can reproduce the container.
pub fn import_xo(manifest: &str) -> Result<Vec<Module>> {
    let j = Json::parse(manifest).map_err(|e| anyhow!("xo manifest: {e}"))?;
    let kernel = j
        .at("kernel")
        .and_then(|k| k.as_str())
        .ok_or_else(|| anyhow!("xo missing kernel"))?;
    let sources = j
        .at("sources")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("xo missing sources"))?;
    let mut out = Vec::new();
    for src in sources {
        let text = src
            .as_str()
            .ok_or_else(|| anyhow!("xo source must be a string"))?;
        for mut m in crate::plugins::importer::import_verilog(text)? {
            crate::plugins::pragma::apply_pragmas(&mut m, text)?;
            if m.name == kernel {
                m.metadata.insert("xo_manifest", Json::str(manifest));
                m.metadata.insert("xo_kernel", Json::Bool(true));
            }
            out.push(m);
        }
    }
    if !out.iter().any(|m| m.name == kernel) {
        return Err(anyhow!("kernel '{kernel}' not found in xo sources"));
    }
    out.sort_by_key(|m| if m.name == kernel { 0 } else { 1 });
    Ok(out)
}

/// Export a kernel subtree back into an XO manifest ("outputs the
/// optimized design in the same format").
pub fn export_xo(design: &Design, kernel: &str) -> Result<String> {
    let top = design
        .module(kernel)
        .ok_or_else(|| anyhow!("kernel '{kernel}' not in design"))?;
    // Collect the kernel's reachable modules.
    let mut live = std::collections::BTreeSet::new();
    let mut stack = vec![kernel.to_string()];
    while let Some(n) = stack.pop() {
        if !live.insert(n.clone()) {
            continue;
        }
        if let Some(m) = design.module(&n) {
            for i in m.instances() {
                stack.push(i.module_name.clone());
            }
        }
    }
    let mut sources = Vec::new();
    let mut seen_src: std::collections::BTreeSet<String> = Default::default();
    for n in &live {
        let m = design.module(n).unwrap();
        match &m.body {
            Body::Leaf {
                format: SourceFormat::Verilog,
                source,
            } => {
                if seen_src.insert(source.clone()) {
                    sources.push(Json::str(source));
                }
            }
            Body::Grouped { .. } => {
                sources.push(Json::str(crate::plugins::exporter::grouped_to_verilog(
                    design, m,
                )?));
            }
            _ => {}
        }
    }
    let mut o = JsonObj::new();
    o.insert("kernel", Json::str(kernel));
    o.insert("top", Json::str(&top.name));
    o.insert("sources", Json::Arr(sources));
    Ok(Json::Obj(o).pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> String {
        let krnl = r#"
module krnl_knn (
  input wire ap_clk,
  input wire ap_rst_n,
  output wire [511:0] m_axi_WDATA,
  output wire m_axi_WVALID,
  input wire m_axi_WREADY
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern=m_axi_{bundle}{role} role.valid=VALID role.ready=READY role.data=.*
  dist_core c0 (.clk(ap_clk));
endmodule
module dist_core (input wire clk);
endmodule
"#;
        let mut o = JsonObj::new();
        o.insert("kernel", Json::str("krnl_knn"));
        o.insert("sources", Json::Arr(vec![Json::str(krnl)]));
        Json::Obj(o).dump()
    }

    #[test]
    fn xo_import_kernel_first_with_interfaces() {
        let mods = import_xo(&manifest()).unwrap();
        assert_eq!(mods.len(), 2);
        assert_eq!(mods[0].name, "krnl_knn");
        assert!(mods[0].metadata.contains_key("xo_kernel"));
        assert_eq!(
            mods[0].interface_of("m_axi_WDATA").unwrap().kind(),
            "handshake"
        );
    }

    #[test]
    fn xo_roundtrip() {
        let mods = import_xo(&manifest()).unwrap();
        let mut d = Design::new("krnl_knn");
        for m in mods {
            d.add(m);
        }
        let exported = export_xo(&d, "krnl_knn").unwrap();
        let re = import_xo(&exported).unwrap();
        assert_eq!(re[0].name, "krnl_knn");
        assert_eq!(re.len(), 2);
    }

    #[test]
    fn missing_kernel_rejected() {
        let bad = r#"{"kernel": "nope", "sources": ["module a(); endmodule"]}"#;
        assert!(import_xo(bad).is_err());
    }
}

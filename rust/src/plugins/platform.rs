//! Platform Analyzer (§3.2): "interfaces with vendor tools to collect
//! data" such as per-module resource utilization. Our vendor surrogate is
//! the synthesis estimator; this plugin runs it over every leaf module
//! missing a `resource` entry and writes the result into metadata, so the
//! floorplanner and the EDA simulator agree on one characterization.

use crate::eda::synth::SynthEstimator;
use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use crate::timing::netlist::ModuleCharacteristics;
use crate::util::json::{Json, JsonObj};

/// Pass form of [`analyze`], so platform analysis composes in pipelines
/// like any §3.3 transformation (registry name `platform-analyze`).
pub struct PlatformAnalyze;

impl Pass for PlatformAnalyze {
    fn name(&self) -> &'static str {
        "platform-analyze"
    }

    fn description(&self) -> &'static str {
        "Annotate leaf modules missing resource/timing metadata (vendor surrogate)"
    }

    fn index_policy(&self) -> IndexPolicy {
        // Writes only metadata on leaf modules; connectivity caches
        // (grouped modules' nets) are untouched.
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> anyhow::Result<()> {
        let n = analyze(design);
        if n > 0 {
            ctx.log(format!("platform-analyze: annotated {n} modules"));
        }
        Ok(())
    }
}

/// Annotate every leaf module lacking resource/timing metadata.
/// Returns the number of modules annotated.
pub fn analyze(design: &mut Design) -> usize {
    let est = SynthEstimator::default();
    let mut annotated = 0;
    let names: Vec<String> = design.modules.keys().cloned().collect();
    for name in names {
        let m = design.module_mut(&name).unwrap();
        if !m.is_leaf() {
            continue;
        }
        let mut touched = false;
        if !m.metadata.contains_key("resource") {
            let r = est.resources(m);
            m.metadata
                .insert("resource", crate::ir::builder::resources_to_json(&r));
            touched = true;
        }
        if !m.metadata.contains_key("timing") {
            let t = est.internal_ns(m);
            let mut to = JsonObj::new();
            to.insert("internal_ns", Json::num(t));
            m.metadata.insert("timing", Json::Obj(to));
            touched = true;
        }
        if touched {
            annotated += 1;
        }
    }
    annotated
}

/// Total resources of the design (sum over leaf instances, respecting the
/// hierarchy) — what the "report_utilization" vendor call would return.
pub fn total_resources(design: &Design) -> Resources {
    let nl = crate::eda::vivado::elaborate(design);
    nl.total_resources()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;

    #[test]
    fn annotates_only_missing() {
        let mut d = Design::new("T");
        d.add(
            LeafBuilder::new(
                "A",
                SourceFormat::Verilog,
                "module A(input clk);\nreg [31:0] x;\nalways @(posedge clk) x <= x + 1;\nendmodule",
            )
            .port("clk", Dir::In, 1)
            .build(),
        );
        d.add(
            LeafBuilder::verilog_stub("B")
                .resource(Resources::new(7.0, 7.0, 0.0, 0.0, 0.0))
                .build(),
        );
        d.add(Module::grouped("T"));
        let n = analyze(&mut d);
        assert_eq!(n, 2); // A gets both; B gets timing only
        let a = d.module("A").unwrap();
        assert!(crate::ir::builder::module_resources(a).unwrap().ff >= 32.0);
        let b = d.module("B").unwrap();
        assert_eq!(crate::ir::builder::module_resources(b).unwrap().lut, 7.0);
        assert!(b.metadata.contains_key("timing"));
    }

    #[test]
    fn idempotent() {
        let mut d = Design::new("T");
        d.add(LeafBuilder::verilog_stub("A").build());
        d.add(Module::grouped("T"));
        analyze(&mut d);
        let once = d.clone();
        let n = analyze(&mut d);
        assert_eq!(n, 0);
        assert_eq!(d, once);
    }
}

//! Platform Analyzer (§3.2): "interfaces with vendor tools to collect
//! data" such as per-module resource utilization. Our vendor surrogate is
//! the synthesis estimator; this plugin runs it over every leaf module
//! missing a `resource` entry and writes the result into metadata, so the
//! floorplanner and the EDA simulator agree on one characterization.

use crate::eda::synth::{CharMemo, SynthEstimator};
use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use crate::timing::netlist::ModuleCharacteristics;
use crate::util::json::{Json, JsonObj};

/// Pass form of [`analyze`], so platform analysis composes in pipelines
/// like any §3.3 transformation (registry name `platform-analyze`).
pub struct PlatformAnalyze;

impl Pass for PlatformAnalyze {
    fn name(&self) -> &'static str {
        "platform-analyze"
    }

    fn description(&self) -> &'static str {
        "Annotate leaf modules missing resource/timing metadata (vendor surrogate)"
    }

    fn index_policy(&self) -> IndexPolicy {
        // Writes only metadata on leaf modules; connectivity caches
        // (grouped modules' nets) are untouched.
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> anyhow::Result<()> {
        let n = analyze_with(design, ctx.chars.as_deref());
        if n > 0 {
            ctx.log(format!("platform-analyze: annotated {n} modules"));
        }
        Ok(())
    }
}

/// Annotate every leaf module lacking resource/timing metadata.
/// Returns the number of modules annotated.
pub fn analyze(design: &mut Design) -> usize {
    analyze_with(design, None)
}

/// [`analyze`] with an optional characterization memo (the incremental
/// re-flow path): annotated values are identical with or without the
/// memo — `internal_ns` is a pure function of the characterized
/// resources whether those come from metadata, source, or the cache —
/// so cache state can never change an output byte.
pub fn analyze_with(design: &mut Design, memo: Option<&CharMemo>) -> usize {
    let est = SynthEstimator::default();
    let mut annotated = 0;
    let names: Vec<String> = design.modules.keys().cloned().collect();
    for name in names {
        let m = design.module_mut(&name).unwrap();
        if !m.is_leaf() {
            continue;
        }
        let need_r = !m.metadata.contains_key("resource");
        let need_t = !m.metadata.contains_key("timing");
        if !need_r && !need_t {
            continue;
        }
        // One characterization serves both annotations.
        let (r, t) = match memo {
            Some(c) => c.characterize(m),
            None => (est.resources(m), est.internal_ns(m)),
        };
        if need_r {
            m.metadata
                .insert("resource", crate::ir::builder::resources_to_json(&r));
        }
        if need_t {
            let mut to = JsonObj::new();
            to.insert("internal_ns", Json::num(t));
            m.metadata.insert("timing", Json::Obj(to));
        }
        annotated += 1;
    }
    annotated
}

/// Total resources of the design (sum over leaf instances, respecting the
/// hierarchy) — what the "report_utilization" vendor call would return.
pub fn total_resources(design: &Design) -> Resources {
    let nl = crate::eda::vivado::elaborate(design);
    nl.total_resources()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;

    #[test]
    fn annotates_only_missing() {
        let mut d = Design::new("T");
        d.add(
            LeafBuilder::new(
                "A",
                SourceFormat::Verilog,
                "module A(input clk);\nreg [31:0] x;\nalways @(posedge clk) x <= x + 1;\nendmodule",
            )
            .port("clk", Dir::In, 1)
            .build(),
        );
        d.add(
            LeafBuilder::verilog_stub("B")
                .resource(Resources::new(7.0, 7.0, 0.0, 0.0, 0.0))
                .build(),
        );
        d.add(Module::grouped("T"));
        let n = analyze(&mut d);
        assert_eq!(n, 2); // A gets both; B gets timing only
        let a = d.module("A").unwrap();
        assert!(crate::ir::builder::module_resources(a).unwrap().ff >= 32.0);
        let b = d.module("B").unwrap();
        assert_eq!(crate::ir::builder::module_resources(b).unwrap().lut, 7.0);
        assert!(b.metadata.contains_key("timing"));
    }

    #[test]
    fn memoized_analyze_is_byte_identical() {
        let mk = || {
            let mut d = Design::new("T");
            d.add(
                LeafBuilder::new(
                    "A",
                    SourceFormat::Verilog,
                    "module A(input clk);\nreg [31:0] x;\nalways @(posedge clk) x <= x + 1;\nendmodule",
                )
                .port("clk", Dir::In, 1)
                .build(),
            );
            d.add(
                LeafBuilder::verilog_stub("B")
                    .resource(Resources::new(7.0, 7.0, 0.0, 0.0, 0.0))
                    .build(),
            );
            d.add(Module::grouped("T"));
            d
        };
        let mut plain = mk();
        let n_plain = analyze(&mut plain);
        let memo = CharMemo::new(16);
        let mut memoized = mk();
        let n_memo = analyze_with(&mut memoized, Some(&memo));
        assert_eq!(n_plain, n_memo);
        let dump = |d: &Design| crate::ir::schema::design_to_json(d).dump();
        assert_eq!(dump(&plain), dump(&memoized));
        // A second design through the same memo hits the cache and still
        // produces identical bytes.
        let mut warm = mk();
        analyze_with(&mut warm, Some(&memo));
        assert_eq!(dump(&plain), dump(&warm));
        assert!(memo.stats().hits >= 1, "{:?}", memo.stats());
    }

    #[test]
    fn idempotent() {
        let mut d = Design::new("T");
        d.add(LeafBuilder::verilog_stub("A").build());
        d.add(Module::grouped("T"));
        analyze(&mut d);
        let once = d.clone();
        let n = analyze(&mut d);
        assert_eq!(n, 0);
        assert_eq!(d, once);
    }
}

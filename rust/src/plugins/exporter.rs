//! Design Exporter (§3.2): generate the final output from the IR for
//! downstream EDA tools. Unchanged leaf modules are emitted with their
//! original source intact; grouped modules are printed as structural
//! Verilog; floorplan metadata becomes a constraints file (XDC-style
//! pblock assignments).

use crate::ir::core::*;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Exported artifact set: file name -> content.
#[derive(Debug, Clone, Default)]
pub struct ExportBundle {
    pub files: BTreeMap<String, String>,
}

impl ExportBundle {
    pub fn file(&self, name: &str) -> Option<&str> {
        self.files.get(name).map(|s| s.as_str())
    }

    pub fn write_to_dir(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, content) in &self.files {
            std::fs::write(dir.join(name), content)?;
        }
        Ok(())
    }
}

/// Export the design: one Verilog file for the structural hierarchy
/// (grouped modules), one per leaf source kind, plus constraints.
pub fn export(design: &Design) -> Result<ExportBundle> {
    let mut bundle = ExportBundle::default();
    let mut structural = String::new();
    let mut leaves = String::new();
    let mut emitted_sources: std::collections::BTreeSet<&str> = Default::default();

    for m in design.modules.values() {
        match &m.body {
            Body::Grouped { .. } => {
                structural.push_str(&grouped_to_verilog(design, m)?);
                structural.push('\n');
            }
            Body::Leaf { format, source } => match format {
                SourceFormat::Verilog => {
                    // Multiple IR modules may share one source file; emit
                    // each distinct source once, verbatim.
                    if emitted_sources.insert(source.as_str()) {
                        leaves.push_str(source);
                        if !source.ends_with('\n') {
                            leaves.push('\n');
                        }
                        leaves.push('\n');
                    }
                }
                SourceFormat::Vhdl => {
                    bundle
                        .files
                        .insert(format!("{}.vhd", m.name), source.clone());
                }
                SourceFormat::Xci | SourceFormat::Xo => {
                    bundle
                        .files
                        .insert(format!("{}.{}", m.name, format.as_str()), source.clone());
                }
                SourceFormat::Netlist | SourceFormat::Blackbox => {
                    // Stub so the hierarchy elaborates; the netlist/binary
                    // travels alongside.
                    leaves.push_str(&crate::ir::builder::stub_verilog(&m.name, &m.ports));
                    leaves.push('\n');
                }
            },
        }
    }
    bundle.files.insert("design_top.v".into(), structural);
    bundle.files.insert("design_leaves.v".into(), leaves);
    bundle
        .files
        .insert("constraints.xdc".into(), constraints_xdc(design));
    Ok(bundle)
}

/// Print a grouped module as structural Verilog.
pub fn grouped_to_verilog(design: &Design, m: &Module) -> Result<String> {
    let mut s = format!("module {} (\n", m.name);
    for (i, p) in m.ports.iter().enumerate() {
        let dir = match p.dir {
            Dir::In => "input  wire",
            Dir::Out => "output wire",
            Dir::InOut => "inout  wire",
        };
        let range = if p.width > 1 {
            format!("[{}:0] ", p.width - 1)
        } else {
            String::new()
        };
        let comma = if i + 1 < m.ports.len() { "," } else { "" };
        s.push_str(&format!("  {dir} {range}{}{comma}\n", p.name));
    }
    s.push_str(");\n");
    for w in m.wires() {
        let range = if w.width > 1 {
            format!("[{}:0] ", w.width - 1)
        } else {
            String::new()
        };
        s.push_str(&format!("  wire {range}{};\n", w.name));
    }
    for inst in m.instances() {
        if design.module(&inst.module_name).is_none() {
            bail!(
                "instance '{}' references unknown module '{}'",
                inst.instance_name,
                inst.module_name
            );
        }
        s.push_str(&format!("  {} {} (\n", inst.module_name, inst.instance_name));
        for (i, c) in inst.connections.iter().enumerate() {
            let v = match &c.value {
                ConnExpr::Id(id) => id.clone(),
                ConnExpr::Const { width, value } => format!("{width}'d{value}"),
                ConnExpr::Open => String::new(),
            };
            let comma = if i + 1 < inst.connections.len() { "," } else { "" };
            s.push_str(&format!("    .{}({v}){comma}\n", c.port));
        }
        s.push_str("  );\n");
    }
    // Interface pragmas so a re-import of the structural Verilog
    // reconstructs the module's interface declarations (round-trip
    // oracle: export → import must not lose interface information).
    s.push_str(&crate::plugins::pragma::pragma_comments(m));
    s.push_str("endmodule\n");
    Ok(s)
}

/// XDC-style pblock constraints from `floorplan` metadata on instances
/// (hierarchical paths) and modules.
pub fn constraints_xdc(design: &Design) -> String {
    let mut s = String::from("# RapidStream IR floorplan constraints\n");
    let mut emit = |path: &str, slot: &str| {
        s.push_str(&format!(
            "add_cells_to_pblock [get_pblocks {slot}] [get_cells {{{path}}}]\n"
        ));
    };
    // Walk hierarchy from the top for instance paths.
    fn walk(
        design: &Design,
        m: &Module,
        prefix: &str,
        emit: &mut dyn FnMut(&str, &str),
    ) {
        for inst in m.instances() {
            let path = if prefix.is_empty() {
                inst.instance_name.clone()
            } else {
                format!("{prefix}/{}", inst.instance_name)
            };
            if let Some(slot) = inst.metadata.get("floorplan").and_then(|f| f.as_str()) {
                emit(&path, slot);
            } else if let Some(sub) = design.module(&inst.module_name) {
                if let Some(slot) = sub.metadata.get("floorplan").and_then(|f| f.as_str()) {
                    emit(&path, slot);
                }
            }
            if let Some(sub) = design.module(&inst.module_name) {
                if sub.is_grouped() {
                    walk(design, sub, &path, emit);
                }
            }
        }
    }
    walk(design, design.top_module(), "", &mut emit);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::util::json::Json;

    fn sample() -> Design {
        let a = LeafBuilder::verilog_stub("A")
            .handshake("o", Dir::Out, 8)
            .build();
        let b = LeafBuilder::verilog_stub("B")
            .handshake("i", Dir::In, 8)
            .build();
        let mut top = GroupedBuilder::new("Top")
            .wire("d", 8)
            .wire("d_vld", 1)
            .wire("d_rdy", 1)
            .inst("a0", "A", &[("o", "d"), ("o_vld", "d_vld"), ("o_rdy", "d_rdy")])
            .inst("b0", "B", &[("i", "d"), ("i_vld", "d_vld"), ("i_rdy", "d_rdy")])
            .build();
        top.instances_mut()[0]
            .metadata
            .insert("floorplan", Json::str("SLOT_X0Y0"));
        top.instances_mut()[1]
            .metadata
            .insert("floorplan", Json::str("SLOT_X1Y2"));
        let mut d = Design::new("Top");
        d.add(a);
        d.add(b);
        d.add(top);
        d
    }

    #[test]
    fn export_produces_reimportable_verilog() {
        let d = sample();
        let bundle = export(&d).unwrap();
        let top_v = bundle.file("design_top.v").unwrap();
        let leaves_v = bundle.file("design_leaves.v").unwrap();
        // Both files parse.
        let ftop = crate::verilog::parser::parse_file(top_v).unwrap();
        let fleaves = crate::verilog::parser::parse_file(leaves_v).unwrap();
        assert_eq!(ftop.modules.len(), 1);
        assert_eq!(fleaves.modules.len(), 2);
        // The structural module instantiates both leaves.
        let top = ftop.module("Top").unwrap();
        assert_eq!(top.instances().count(), 2);
    }

    #[test]
    fn leaf_sources_verbatim() {
        let d = sample();
        let bundle = export(&d).unwrap();
        let Body::Leaf { source, .. } = &d.module("A").unwrap().body else {
            panic!()
        };
        assert!(bundle.file("design_leaves.v").unwrap().contains(source.as_str()));
    }

    #[test]
    fn constraints_contain_pblocks() {
        let d = sample();
        let xdc = constraints_xdc(&d);
        assert!(xdc.contains("add_cells_to_pblock [get_pblocks SLOT_X0Y0] [get_cells {a0}]"));
        assert!(xdc.contains("SLOT_X1Y2"));
    }

    #[test]
    fn open_and_const_connections_rendered() {
        let mut d = sample();
        let top = d.module_mut("Top").unwrap();
        top.instances_mut()[0].connect("dbg", ConnExpr::Open);
        top.instances_mut()[0].connect("cfg", ConnExpr::Const { width: 4, value: 5 });
        // (A doesn't have these ports; rendering shouldn't care.)
        let s = grouped_to_verilog(&d, d.module("Top").unwrap()).unwrap();
        assert!(s.contains(".dbg()"));
        assert!(s.contains(".cfg(4'd5)"));
    }

    #[test]
    fn grouped_pragmas_reconstruct_interfaces_on_reimport() {
        let mut d = sample();
        let top = d.module_mut("Top").unwrap();
        top.ports.push(Port::new("ap_clk", Dir::In, 1));
        top.interfaces.push(Interface::Clock {
            port: "ap_clk".into(),
        });
        let s = grouped_to_verilog(&d, d.module("Top").unwrap()).unwrap();
        assert!(s.contains("// pragma clock port=ap_clk module=Top"), "{s}");
        let mut ms = crate::plugins::importer::import_verilog(&s).unwrap();
        crate::plugins::pragma::apply_pragmas(&mut ms[0], &s).unwrap();
        assert_eq!(ms[0].interface_of("ap_clk").unwrap().kind(), "clock");
    }

    #[test]
    fn unknown_module_ref_fails() {
        let mut d = sample();
        d.module_mut("Top")
            .unwrap()
            .instances_mut()
            .push(Instance::new("g", "Ghost"));
        assert!(export(&d).is_err());
    }
}

//! Xilinx Compiled IP (XCI) importer — JSON-manifest surrogate.
//!
//! Real .xci files are Vivado-internal XML/JSON describing a configured
//! IP: name, ports, and bus interfaces. Our surrogate keeps the same
//! information in a JSON manifest embedded verbatim in the IR (the IP's
//! "binary" is opaque to RIR anyway — it is a leaf by definition):
//!
//! ```json
//! {
//!   "ip_name": "axi_datamover_0",
//!   "vlnv": "xilinx.com:ip:axi_datamover:5.1",
//!   "ports": [{"name": "s_axis_tdata", "direction": "in", "width": 64}],
//!   "bus_interfaces": [
//!     {"name": "S_AXIS", "type": "axis",
//!      "data": ["s_axis_tdata"], "valid": "s_axis_tvalid",
//!      "ready": "s_axis_tready"}
//!   ],
//!   "resource": {"LUT": 2100, "FF": 3300, "BRAM": 4, "DSP": 0, "URAM": 0}
//! }
//! ```

use crate::ir::core::*;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Import an XCI manifest into a leaf module with interfaces attached
/// ("Xilinx IPs include interface details in XCI files", §3.2).
pub fn import_xci(manifest: &str) -> Result<Module> {
    let j = Json::parse(manifest).map_err(|e| anyhow!("xci manifest: {e}"))?;
    let name = j
        .at("ip_name")
        .and_then(|n| n.as_str())
        .ok_or_else(|| anyhow!("xci missing ip_name"))?;
    let mut m = Module::leaf(name, SourceFormat::Xci, manifest);
    for pj in j
        .at("ports")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow!("xci missing ports"))?
    {
        m.ports.push(Port::new(
            pj.at("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("xci port missing name"))?,
            pj.at("direction")
                .and_then(|d| d.as_str())
                .and_then(Dir::parse)
                .ok_or_else(|| anyhow!("xci port missing direction"))?,
            pj.at("width").and_then(|w| w.as_u64()).unwrap_or(1) as u32,
        ));
    }
    if let Some(ifaces) = j.at("bus_interfaces").and_then(|i| i.as_arr()) {
        for ij in ifaces {
            let iname = ij.at("name").and_then(|n| n.as_str()).unwrap_or("bus");
            match ij.at("type").and_then(|t| t.as_str()) {
                Some("axis") | Some("handshake") => {
                    let data = ij
                        .at("data")
                        .and_then(|d| d.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                                .collect()
                        })
                        .unwrap_or_default();
                    m.interfaces.push(Interface::Handshake {
                        name: iname.to_string(),
                        data,
                        valid: ij
                            .at("valid")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| anyhow!("axis iface missing valid"))?
                            .to_string(),
                        ready: ij
                            .at("ready")
                            .and_then(|r| r.as_str())
                            .ok_or_else(|| anyhow!("axis iface missing ready"))?
                            .to_string(),
                        clk: ij.at("clk").and_then(|c| c.as_str()).map(|s| s.to_string()),
                    });
                }
                Some("clock") => {
                    if let Some(p) = ij.at("port").and_then(|p| p.as_str()) {
                        m.interfaces.push(Interface::Clock { port: p.into() });
                    }
                }
                Some("reset") => {
                    if let Some(p) = ij.at("port").and_then(|p| p.as_str()) {
                        m.interfaces.push(Interface::Reset {
                            port: p.into(),
                            active_high: ij
                                .at("active_high")
                                .and_then(|a| a.as_bool())
                                .unwrap_or(true),
                        });
                    }
                }
                _ => {}
            }
        }
    }
    if let Some(r) = j.at("resource") {
        m.metadata.insert("resource", r.clone());
    }
    Ok(m)
}

/// Build an XCI manifest for a module (exporter direction — used by the
/// benchmark generators to fabricate vendor IPs).
pub fn manifest_for(
    ip_name: &str,
    vlnv: &str,
    ports: &[(String, Dir, u32)],
    resource: &Resources,
) -> String {
    use crate::util::json::JsonObj;
    let mut o = JsonObj::new();
    o.insert("ip_name", Json::str(ip_name));
    o.insert("vlnv", Json::str(vlnv));
    o.insert(
        "ports",
        Json::Arr(
            ports
                .iter()
                .map(|(n, d, w)| {
                    let mut po = JsonObj::new();
                    po.insert("name", Json::str(n));
                    po.insert("direction", Json::str(d.as_str()));
                    po.insert("width", Json::num(*w as f64));
                    Json::Obj(po)
                })
                .collect(),
        ),
    );
    o.insert(
        "resource",
        crate::ir::builder::resources_to_json(resource),
    );
    Json::Obj(o).pretty()
}

/// Build an XCI manifest from an IR module — the inverse of
/// [`import_xci`], used by `designs::synthetic` to materialize vendor-IP
/// surrogate leaves on the text path. Ports, clock/reset/handshake bus
/// interfaces, and resource metadata all survive a round trip through
/// [`import_xci`]; feedforward/non-pipeline interfaces have no XCI bus
/// type (callers qualify with `designs::synthetic::effective_source`).
pub fn module_manifest(m: &Module) -> String {
    use crate::util::json::JsonObj;
    let mut o = JsonObj::new();
    o.insert("ip_name", Json::str(&m.name));
    o.insert("vlnv", Json::str(format!("rsir:ip:{}:1.0", m.name)));
    o.insert(
        "ports",
        Json::Arr(
            m.ports
                .iter()
                .map(|p| {
                    let mut po = JsonObj::new();
                    po.insert("name", Json::str(&p.name));
                    po.insert("direction", Json::str(p.dir.as_str()));
                    po.insert("width", Json::num(p.width as f64));
                    Json::Obj(po)
                })
                .collect(),
        ),
    );
    let mut ifaces = Vec::new();
    for iface in &m.interfaces {
        match iface {
            Interface::Clock { port } => {
                let mut io = JsonObj::new();
                io.insert("name", Json::str(port));
                io.insert("type", Json::str("clock"));
                io.insert("port", Json::str(port));
                ifaces.push(Json::Obj(io));
            }
            Interface::Reset { port, active_high } => {
                let mut io = JsonObj::new();
                io.insert("name", Json::str(port));
                io.insert("type", Json::str("reset"));
                io.insert("port", Json::str(port));
                io.insert("active_high", Json::Bool(*active_high));
                ifaces.push(Json::Obj(io));
            }
            Interface::Handshake {
                name,
                data,
                valid,
                ready,
                clk,
            } => {
                let mut io = JsonObj::new();
                io.insert("name", Json::str(name));
                io.insert("type", Json::str("handshake"));
                io.insert(
                    "data",
                    Json::Arr(data.iter().map(Json::str).collect()),
                );
                io.insert("valid", Json::str(valid));
                io.insert("ready", Json::str(ready));
                if let Some(c) = clk {
                    io.insert("clk", Json::str(c));
                }
                ifaces.push(Json::Obj(io));
            }
            Interface::Feedforward { .. } | Interface::NonPipeline { .. } => {}
        }
    }
    if !ifaces.is_empty() {
        o.insert("bus_interfaces", Json::Arr(ifaces));
    }
    if let Some(r) = m.metadata.get("resource") {
        o.insert("resource", r.clone());
    }
    Json::Obj(o).pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "ip_name": "hbm_axi_bridge_0",
      "vlnv": "xilinx.com:ip:hbm_axi_bridge:1.0",
      "ports": [
        {"name": "aclk", "direction": "in", "width": 1},
        {"name": "s_tdata", "direction": "in", "width": 256},
        {"name": "s_tvalid", "direction": "in", "width": 1},
        {"name": "s_tready", "direction": "out", "width": 1}
      ],
      "bus_interfaces": [
        {"name": "S", "type": "axis", "data": ["s_tdata"],
         "valid": "s_tvalid", "ready": "s_tready", "clk": "aclk"},
        {"name": "CLK", "type": "clock", "port": "aclk"}
      ],
      "resource": {"LUT": 2100, "FF": 3300, "BRAM": 4, "DSP": 0, "URAM": 0}
    }"#;

    #[test]
    fn imports_ports_interfaces_resources() {
        let m = import_xci(MANIFEST).unwrap();
        assert_eq!(m.name, "hbm_axi_bridge_0");
        assert_eq!(m.ports.len(), 4);
        assert_eq!(m.port("s_tdata").unwrap().width, 256);
        assert_eq!(m.interface_of("s_tdata").unwrap().kind(), "handshake");
        assert_eq!(m.interface_of("aclk").unwrap().kind(), "clock");
        let r = crate::ir::builder::module_resources(&m).unwrap();
        assert_eq!(r.lut, 2100.0);
        assert!(matches!(
            m.body,
            Body::Leaf {
                format: SourceFormat::Xci,
                ..
            }
        ));
    }

    #[test]
    fn manifest_roundtrip() {
        let ports = vec![
            ("clk".to_string(), Dir::In, 1),
            ("q".to_string(), Dir::Out, 32),
        ];
        let man = manifest_for(
            "my_ip_0",
            "acme:ip:my_ip:1.0",
            &ports,
            &Resources::new(10.0, 20.0, 0.0, 0.0, 0.0),
        );
        let m = import_xci(&man).unwrap();
        assert_eq!(m.name, "my_ip_0");
        assert_eq!(m.port("q").unwrap().width, 32);
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(import_xci("not json").is_err());
        assert!(import_xci(r#"{"ports": []}"#).is_err());
    }

    #[test]
    fn module_manifest_roundtrips_interfaces_and_resource() {
        let m = crate::ir::builder::LeafBuilder::verilog_stub("ip0")
            .clk_rst()
            .handshake("b0", Dir::In, 32)
            .handshake("b1", Dir::Out, 8)
            .resource(Resources::new(10.0, 20.0, 1.0, 2.0, 0.0))
            .build();
        let man = module_manifest(&m);
        let re = import_xci(&man).unwrap();
        assert_eq!(re.name, "ip0");
        assert_eq!(re.ports, m.ports);
        assert_eq!(re.interfaces, m.interfaces);
        let r = crate::ir::builder::module_resources(&re).unwrap();
        assert_eq!((r.lut, r.ff), (10.0, 20.0));
        // The re-imported module embeds the manifest verbatim, so a
        // second round trip is textually stable.
        let Body::Leaf {
            source,
            format: SourceFormat::Xci,
        } = &re.body
        else {
            panic!("expected xci leaf body")
        };
        assert_eq!(*source, man);
    }
}

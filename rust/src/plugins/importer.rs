//! Leaf Module Importer (§3.2): builds IR leaf modules from design
//! sources. "To maintain the design integrity, the source code or its
//! binary is directly embedded in the IR."

use crate::ir::core::*;
use crate::verilog::parser::parse_file;
use anyhow::{anyhow, Result};

/// Import every module of a Verilog source as leaf modules (one IR module
/// per Verilog module; the source text embedded verbatim in each).
pub fn import_verilog(source: &str) -> Result<Vec<Module>> {
    let file = parse_file(source)?;
    if file.modules.is_empty() {
        return Err(anyhow!("no modules found in source"));
    }
    let mut out = Vec::new();
    for vm in &file.modules {
        let mut m = Module::leaf(&vm.name, SourceFormat::Verilog, source);
        m.ports = vm
            .ports
            .iter()
            .map(|p| Port::new(&p.name, p.dir, p.width))
            .collect();
        out.push(m);
    }
    Ok(out)
}

/// Import a set of Verilog sources into a design with the given top.
/// Pragma comments in each source are applied (see
/// [`crate::plugins::pragma`]).
pub fn import_design(top: &str, sources: &[&str]) -> Result<Design> {
    let mut d = Design::new(top);
    for src in sources {
        for mut m in import_verilog(src)? {
            crate::plugins::pragma::apply_pragmas(&mut m, src)?;
            d.add(m);
        }
    }
    if d.module(top).is_none() {
        return Err(anyhow!("top module '{top}' not found in sources"));
    }
    Ok(d)
}

/// Import a VHDL entity via its signature (the paper routes VHDL through
/// "transforming module signatures into a Verilog stub file using EDA
/// tools, followed by the Verilog importer" — our surrogate parses the
/// entity/port declaration directly and embeds the VHDL verbatim).
pub fn import_vhdl(source: &str) -> Result<Module> {
    let lower = source.to_lowercase();
    let ent_pos = lower
        .find("entity ")
        .ok_or_else(|| anyhow!("no entity declaration"))?;
    let after = &source[ent_pos + 7..];
    let name: String = after
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    let mut m = Module::leaf(&name, SourceFormat::Vhdl, source);
    // port ( name : in|out std_logic[_vector(msb downto lsb)] ; ... );
    if let Some(pstart) = lower.find("port") {
        let body = &source[pstart..];
        let open = body.find('(').ok_or_else(|| anyhow!("bad port clause"))?;
        // find matching close paren
        let mut depth = 0usize;
        let mut end = open;
        for (i, c) in body.char_indices().skip(open) {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i;
                        break;
                    }
                }
                _ => {}
            }
        }
        let ports_text = &body[open + 1..end];
        for decl in ports_text.split(';') {
            let Some((names, ty)) = decl.split_once(':') else {
                continue;
            };
            let ty_l = ty.trim().to_lowercase();
            let dir = if ty_l.starts_with("inout") {
                Dir::InOut
            } else if ty_l.starts_with("in") {
                Dir::In
            } else if ty_l.starts_with("out") {
                Dir::Out
            } else {
                continue;
            };
            let width = if let Some(dt) = ty_l.find("downto") {
                // (msb downto lsb)
                let before: String = ty_l[..dt]
                    .chars()
                    .rev()
                    .skip_while(|c| c.is_whitespace())
                    .take_while(|c| c.is_ascii_digit())
                    .collect();
                let msb: u32 = before.chars().rev().collect::<String>().parse().unwrap_or(0);
                let after_dt: String = ty_l[dt + 6..]
                    .chars()
                    .skip_while(|c| c.is_whitespace())
                    .take_while(|c| c.is_ascii_digit())
                    .collect();
                let lsb: u32 = after_dt.parse().unwrap_or(0);
                msb - lsb + 1
            } else {
                1
            };
            for n in names.split(',') {
                let n = n.trim();
                if !n.is_empty() {
                    m.ports.push(Port::new(n, dir, width));
                }
            }
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verilog_import_extracts_signature() {
        let src = "module Loader (input wire clk, output wire [63:0] d);\nendmodule";
        let ms = import_verilog(src).unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].name, "Loader");
        assert_eq!(ms[0].port("d").unwrap().width, 64);
        // Source embedded verbatim.
        let Body::Leaf { source, .. } = &ms[0].body else {
            panic!()
        };
        assert_eq!(*source, src);
    }

    #[test]
    fn design_import_requires_top() {
        let src = "module A(); endmodule";
        assert!(import_design("Missing", &[src]).is_err());
        assert!(import_design("A", &[src]).is_ok());
    }

    #[test]
    fn vhdl_entity_import() {
        let src = r#"
library ieee;
entity dyn_fifo is
  port (
    clk     : in  std_logic;
    din     : in  std_logic_vector(31 downto 0);
    dout    : out std_logic_vector(31 downto 0);
    wr, rd  : in  std_logic
  );
end entity;
architecture rtl of dyn_fifo is begin end rtl;
"#;
        let m = import_vhdl(src).unwrap();
        assert_eq!(m.name, "dyn_fifo");
        assert_eq!(m.port("din").unwrap().width, 32);
        assert_eq!(m.port("dout").unwrap().dir, Dir::Out);
        assert_eq!(m.port("wr").unwrap().width, 1);
        assert!(matches!(
            m.body,
            Body::Leaf {
                format: SourceFormat::Vhdl,
                ..
            }
        ));
    }
}

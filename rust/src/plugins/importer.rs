//! Leaf Module Importer (§3.2): builds IR leaf modules from design
//! sources. "To maintain the design integrity, the source code or its
//! binary is directly embedded in the IR."

use crate::ir::core::*;
use crate::verilog::parser::parse_file;
use anyhow::{anyhow, Result};

/// Import every module of a Verilog source as leaf modules (one IR module
/// per Verilog module; each module's own source slice — recovered from its
/// parse span — is embedded verbatim, so a multi-module file does not
/// duplicate the full text into every leaf).
pub fn import_verilog(source: &str) -> Result<Vec<Module>> {
    let file = parse_file(source)?;
    if file.modules.is_empty() {
        return Err(anyhow!("no modules found in source"));
    }
    let mut out = Vec::new();
    for vm in &file.modules {
        let mut m = Module::leaf(&vm.name, SourceFormat::Verilog, vm.source_slice(source));
        m.ports = vm
            .ports
            .iter()
            .map(|p| Port::new(&p.name, p.dir, p.width))
            .collect();
        out.push(m);
    }
    Ok(out)
}

/// Dispatch a source to the right importer by content: Verilog if a
/// `module` header parses, VHDL if an `entity` declaration is present.
/// Mismatched or unrecognizable sources produce a typed error naming both
/// attempts (satisfying the "VHDL-vs-Verilog dispatch" contract).
pub fn import_auto(source: &str) -> Result<Vec<Module>> {
    match import_verilog(source) {
        Ok(ms) => Ok(ms),
        Err(verr) => match import_vhdl(source) {
            Ok(m) => Ok(vec![m]),
            Err(herr) => Err(anyhow!(
                "source is neither importable Verilog nor VHDL \
                 (verilog: {verr}; vhdl: {herr})"
            )),
        },
    }
}

/// Import a set of Verilog sources into a design with the given top.
/// Pragma comments in each source are applied (see
/// [`crate::plugins::pragma`]).
pub fn import_design(top: &str, sources: &[&str]) -> Result<Design> {
    let mut d = Design::new(top);
    for src in sources {
        for mut m in import_verilog(src)? {
            crate::plugins::pragma::apply_pragmas(&mut m, src)?;
            d.add(m);
        }
    }
    if d.module(top).is_none() {
        return Err(anyhow!("top module '{top}' not found in sources"));
    }
    Ok(d)
}

/// Import a mixed-format source set — Verilog text, `.xci` manifests,
/// `.xo` manifests — into one design with the given top (the front door
/// of the Verilog round-trip oracle). Verilog modules get their pragma
/// comments applied; vendor containers carry interface declarations
/// natively.
pub fn import_mixed(
    top: &str,
    verilog: &[String],
    xci: &[String],
    xo: &[String],
) -> Result<Design> {
    let mut d = Design::new(top);
    for src in verilog {
        for mut m in import_verilog(src)? {
            crate::plugins::pragma::apply_pragmas(&mut m, src)?;
            d.add(m);
        }
    }
    for man in xci {
        d.add(crate::plugins::xci::import_xci(man)?);
    }
    for man in xo {
        for m in crate::plugins::xo::import_xo(man)? {
            d.add(m);
        }
    }
    if d.module(top).is_none() {
        return Err(anyhow!("top module '{top}' not found in sources"));
    }
    Ok(d)
}

/// Import a VHDL entity via its signature (the paper routes VHDL through
/// "transforming module signatures into a Verilog stub file using EDA
/// tools, followed by the Verilog importer" — our surrogate parses the
/// entity/port declaration directly and embeds the VHDL verbatim).
pub fn import_vhdl(source: &str) -> Result<Module> {
    let lower = source.to_lowercase();
    let ent_pos = lower
        .find("entity ")
        .ok_or_else(|| anyhow!("no entity declaration"))?;
    let after = &source[ent_pos + 7..];
    let name: String = after
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    let mut m = Module::leaf(&name, SourceFormat::Vhdl, source);
    // port ( name : in|out std_logic[_vector(msb downto lsb)] ; ... );
    if let Some(pstart) = lower.find("port") {
        let body = &source[pstart..];
        let open = body.find('(').ok_or_else(|| anyhow!("bad port clause"))?;
        // find matching close paren
        let mut depth = 0usize;
        let mut end = open;
        for (i, c) in body.char_indices().skip(open) {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i;
                        break;
                    }
                }
                _ => {}
            }
        }
        let ports_text = &body[open + 1..end];
        for decl in ports_text.split(';') {
            let Some((names, ty)) = decl.split_once(':') else {
                continue;
            };
            let ty_l = ty.trim().to_lowercase();
            let dir = if ty_l.starts_with("inout") {
                Dir::InOut
            } else if ty_l.starts_with("in") {
                Dir::In
            } else if ty_l.starts_with("out") {
                Dir::Out
            } else {
                continue;
            };
            let width = if let Some(dt) = ty_l.find("downto") {
                // (msb downto lsb)
                let before: String = ty_l[..dt]
                    .chars()
                    .rev()
                    .skip_while(|c| c.is_whitespace())
                    .take_while(|c| c.is_ascii_digit())
                    .collect();
                let msb: u32 = before.chars().rev().collect::<String>().parse().unwrap_or(0);
                let after_dt: String = ty_l[dt + 6..]
                    .chars()
                    .skip_while(|c| c.is_whitespace())
                    .take_while(|c| c.is_ascii_digit())
                    .collect();
                let lsb: u32 = after_dt.parse().unwrap_or(0);
                msb.saturating_sub(lsb) + 1
            } else {
                1
            };
            for n in names.split(',') {
                let n = n.trim();
                if !n.is_empty() {
                    m.ports.push(Port::new(n, dir, width));
                }
            }
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verilog_import_extracts_signature() {
        let src = "module Loader (input wire clk, output wire [63:0] d);\nendmodule";
        let ms = import_verilog(src).unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].name, "Loader");
        assert_eq!(ms[0].port("d").unwrap().width, 64);
        // Source embedded verbatim.
        let Body::Leaf { source, .. } = &ms[0].body else {
            panic!()
        };
        assert_eq!(*source, src);
    }

    #[test]
    fn multi_module_source_slices_per_module() {
        let src = "// bank\nmodule A(input a); endmodule\nmodule B(output b); endmodule\n";
        let ms = import_verilog(src).unwrap();
        assert_eq!(ms.len(), 2);
        let Body::Leaf { source: sa, .. } = &ms[0].body else { panic!() };
        let Body::Leaf { source: sb, .. } = &ms[1].body else { panic!() };
        assert_eq!(*sa, "module A(input a); endmodule");
        assert_eq!(*sb, "module B(output b); endmodule");
    }

    #[test]
    fn dispatch_errors_name_both_frontends() {
        // VHDL fed to the Verilog importer: typed error, no panic.
        let vhdl = "entity e is port ( c : in std_logic ); end entity;";
        let err = import_verilog(vhdl).unwrap_err();
        assert!(format!("{err}").contains("no modules"), "{err}");
        // Verilog fed to the VHDL importer: typed error, no panic.
        let vlog = "module M(input c); endmodule";
        let err = import_vhdl(vlog).unwrap_err();
        assert!(format!("{err}").contains("entity"), "{err}");
        // Auto-dispatch picks the right frontend either way.
        assert_eq!(import_auto(vlog).unwrap()[0].name, "M");
        assert_eq!(import_auto(vhdl).unwrap()[0].name, "e");
        // Garbage is rejected with both attempts named.
        let err = import_auto("what even is this").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("verilog:") && msg.contains("vhdl:"), "{msg}");
    }

    #[test]
    fn design_import_requires_top() {
        let src = "module A(); endmodule";
        assert!(import_design("Missing", &[src]).is_err());
        assert!(import_design("A", &[src]).is_ok());
    }

    #[test]
    fn mixed_import_combines_all_formats() {
        let top = "module Top (input wire ap_clk);\n\
                   // pragma clock port=ap_clk module=Top\n\
                   endmodule\n"
            .to_string();
        let xci = r#"{"ip_name": "ip0",
            "ports": [{"name": "q", "direction": "out", "width": 8}]}"#
            .to_string();
        let xo = r#"{"kernel": "k0", "sources": ["module k0(input wire c); endmodule"]}"#
            .to_string();
        let d = import_mixed("Top", &[top], &[xci], &[xo]).unwrap();
        assert_eq!(d.modules.len(), 3);
        assert_eq!(
            d.module("Top").unwrap().interface_of("ap_clk").unwrap().kind(),
            "clock"
        );
        assert_eq!(d.module("ip0").unwrap().port("q").unwrap().width, 8);
        assert!(d.module("k0").unwrap().metadata.contains_key("xo_kernel"));
        // Missing top is a typed error.
        assert!(import_mixed("Nope", &[], &[], &[]).is_err());
    }

    #[test]
    fn mixed_import_of_synthetic_sources_is_drc_clean() {
        use crate::designs::synthetic::{materialize_sources, DesignGen, SyntheticConfig};
        use crate::util::rng::Rng;
        // Importing the generator's full source sets — including `.xci`
        // and `.xo` surrogates — must always yield a DRC-clean design:
        // the import direction preserves every rule `materialize`
        // guarantees by construction.
        let gen = DesignGen {
            cfg: SyntheticConfig::default(),
        };
        let mut rng = Rng::new(2);
        let (mut seen_xci, mut seen_xo) = (false, false);
        for _ in 0..32 {
            let srcs = materialize_sources(&gen.generate(&mut rng));
            seen_xci |= !srcs.xci.is_empty();
            seen_xo |= !srcs.xo.is_empty();
            let d = import_mixed(&srcs.top, &srcs.verilog, &srcs.xci, &srcs.xo).unwrap();
            let violations = crate::ir::validate::check(&d);
            assert!(violations.is_empty(), "{violations:?}");
        }
        assert!(seen_xci && seen_xo, "sample never exercised xci/xo paths");
    }

    #[test]
    fn mixed_import_survives_pipeline_drc_clean() {
        use crate::designs::synthetic::materialize_sources;
        use crate::passes::PassContext;
        use crate::util::rng::Rng;
        // Seeded plan: the text path through the analyze pipeline lands
        // DRC-clean, with the hierarchy rediscovered from the imported
        // flat leaves.
        let gen = crate::designs::synthetic::DesignGen::default();
        let mut rng = Rng::new(8);
        let srcs = materialize_sources(&gen.generate(&mut rng));
        let mut d = import_mixed(&srcs.top, &srcs.verilog, &srcs.xci, &srcs.xo).unwrap();
        let mut ctx = PassContext::new();
        crate::testing::oracle::analyze_pipeline(&mut d, &mut ctx).unwrap();
        let violations = crate::ir::validate::check(&d);
        assert!(violations.is_empty(), "{violations:?}");
        assert!(d.module(&d.top).is_some());
    }

    #[test]
    fn vhdl_entity_import() {
        let src = r#"
library ieee;
entity dyn_fifo is
  port (
    clk     : in  std_logic;
    din     : in  std_logic_vector(31 downto 0);
    dout    : out std_logic_vector(31 downto 0);
    wr, rd  : in  std_logic
  );
end entity;
architecture rtl of dyn_fifo is begin end rtl;
"#;
        let m = import_vhdl(src).unwrap();
        assert_eq!(m.name, "dyn_fifo");
        assert_eq!(m.port("din").unwrap().width, 32);
        assert_eq!(m.port("dout").unwrap().dir, Dir::Out);
        assert_eq!(m.port("wr").unwrap().width, 1);
        assert!(matches!(
            m.body,
            Body::Leaf {
                format: SourceFormat::Vhdl,
                ..
            }
        ));
    }
}

//! PJRT-backed [`BatchEvaluator`]: scores floorplan candidates through
//! the AOT-compiled Pallas kernel.
//!
//! The problem is padded into the nearest artifact bucket:
//! * units padded with zero connectivity/resources, parked in slot 0
//!   (cost-neutral — property-tested on the Python side);
//! * slots padded with zero capacity and zero distance (one-hot columns
//!   for padded slots are never set);
//! * the batch padded by repeating the last candidate.

use crate::floorplan::cost::{BatchEvaluator, CostModel, NUM_KINDS};
use crate::runtime::pjrt::{Bucket, Manifest, Runtime};
use anyhow::{anyhow, Result};
use std::path::PathBuf;

pub struct PjrtEvaluator {
    pub model: CostModel,
    runtime: Runtime,
    artifact: PathBuf,
    bucket: Bucket,
    // Pre-padded static operands.
    conn: Vec<f32>,
    dist: Vec<f32>,
    res: Vec<f32>,
    caps: Vec<f32>,
    lam: Vec<f32>,
}

impl PjrtEvaluator {
    /// Build from a cost model + the artifacts directory manifest.
    pub fn new(model: CostModel, manifest: &Manifest) -> Result<PjrtEvaluator> {
        let bucket = manifest
            .pick(model.m, model.s)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact bucket for M={} S={} (have: {:?})",
                    model.m,
                    model.s,
                    manifest.buckets.iter().map(|b| b.units).collect::<Vec<_>>()
                )
            })?
            .clone();
        let (bm, bs) = (bucket.units, bucket.slots);
        // Pad static operands into bucket shape.
        let mut conn = vec![0f32; bm * bm];
        for i in 0..model.m {
            conn[i * bm..i * bm + model.m]
                .copy_from_slice(&model.conn[i * model.m..(i + 1) * model.m]);
        }
        let mut dist = vec![0f32; bs * bs];
        for i in 0..model.s {
            dist[i * bs..i * bs + model.s]
                .copy_from_slice(&model.dist[i * model.s..(i + 1) * model.s]);
        }
        let mut res = vec![0f32; bm * NUM_KINDS];
        res[..model.m * NUM_KINDS].copy_from_slice(&model.res);
        let mut caps = vec![0f32; bs * NUM_KINDS];
        caps[..model.s * NUM_KINDS].copy_from_slice(&model.caps);
        Ok(PjrtEvaluator {
            lam: vec![model.lambda],
            runtime: Runtime::cpu()?,
            artifact: manifest.path_of(&bucket),
            bucket,
            conn,
            dist,
            res,
            caps,
            model,
        })
    }

    /// Evaluate one padded device batch, returning bucket.batch costs.
    fn run_batch(&mut self, a: &[f32]) -> Result<Vec<f32>> {
        let (bb, bm, bs) = (self.bucket.batch, self.bucket.units, self.bucket.slots);
        let outs = self.runtime.execute_f32(
            &self.artifact,
            &[
                (a, &[bb as i64, bm as i64, bs as i64]),
                (&self.conn, &[bm as i64, bm as i64]),
                (&self.dist, &[bs as i64, bs as i64]),
                (&self.res, &[bm as i64, NUM_KINDS as i64]),
                (&self.caps, &[bs as i64, NUM_KINDS as i64]),
                (&self.lam, &[1]),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }
}

impl BatchEvaluator for PjrtEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        let (bb, bm, bs) = (self.bucket.batch, self.bucket.units, self.bucket.slots);
        let mut costs = Vec::with_capacity(batch.len());
        for chunk in batch.chunks(bb) {
            // One-hot into bucket shape; pad rows park in slot 0, pad
            // candidates repeat the last row.
            let mut a = vec![0f32; bb * bm * bs];
            for b in 0..bb {
                let cand = &chunk[b.min(chunk.len() - 1)];
                for i in 0..bm {
                    let slot = if i < self.model.m_real { cand[i] } else { 0 };
                    a[b * bm * bs + i * bs + slot] = 1.0;
                }
            }
            let out = self
                .run_batch(&a)
                .expect("pjrt floorplan-cost execution failed");
            costs.extend_from_slice(&out[..chunk.len()]);
        }
        costs
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::cost::CpuEvaluator;
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;
    use crate::runtime::pjrt::artifacts_dir;
    use crate::util::rng::Rng;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn problem(n: usize) -> Problem {
        Problem {
            units: (0..n)
                .map(|i| Unit {
                    nodes: vec![i],
                    resources: Resources::new(
                        1_000.0 + 321.0 * i as f64,
                        900.0,
                        3.0,
                        12.0,
                        1.0,
                    ),
                    fixed_slot: None,
                    name: format!("u{i}"),
                })
                .collect(),
            edges: (0..n)
                .flat_map(|i| {
                    let mut v = Vec::new();
                    if i + 1 < n {
                        v.push(UnitEdge {
                            a: i,
                            b: i + 1,
                            width: 64,
                        });
                    }
                    if i + 4 < n {
                        v.push(UnitEdge {
                            a: i,
                            b: i + 4,
                            width: 16,
                        });
                    }
                    v
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    /// Invariant 8 of DESIGN.md: CPU oracle == PJRT-executed Pallas HLO.
    #[test]
    fn pjrt_matches_cpu_oracle() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(21);
        let model = CostModel::build(&p, &dev, 0.7, 1e-4);
        let man = Manifest::load(&artifacts_dir()).unwrap();
        let mut pjrt = PjrtEvaluator::new(model.clone(), &man).unwrap();
        let mut cpu = CpuEvaluator { model };
        let mut rng = Rng::new(42);
        let batch: Vec<Vec<usize>> = (0..100)
            .map(|_| (0..21).map(|_| rng.below(dev.num_slots())).collect())
            .collect();
        let a = pjrt.evaluate(&batch);
        let b = cpu.evaluate(&batch);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() <= 1e-3 * y.abs().max(1.0),
                "pjrt {x} vs cpu {y}"
            );
        }
    }

    #[test]
    fn pjrt_sa_end_to_end() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(10);
        let model = CostModel::build(&p, &dev, 0.7, 1e-4);
        let man = Manifest::load(&artifacts_dir()).unwrap();
        let mut ev = PjrtEvaluator::new(model, &man).unwrap();
        let cfg = crate::floorplan::sa::SaConfig {
            steps: 30,
            ..Default::default()
        };
        let r = crate::floorplan::sa::anneal(&p, &dev, &mut ev, None, &cfg);
        assert!(r.best_cost.is_finite());
        assert!(r.evaluated > 1000);
    }
}

//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO text) and
//! executes them from the floorplan-exploration hot path. Python is
//! build-time only — after `make artifacts` the binary is self-contained.

pub mod evaluator;
pub mod pjrt;

pub use evaluator::PjrtEvaluator;
pub use pjrt::{artifacts_dir, Manifest, Runtime};

//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the L3 hot path.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` parses
//! and re-ids the module, `PjRtClient::compile` JITs it once, and the
//! compiled executable is cached for the lifetime of the runtime. Python
//! never runs at this point — `make artifacts` happened at build time.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Lazily-initialized PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached per path).
    pub fn load(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse hlo text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.cache.insert(path.to_path_buf(), exe);
        }
        Ok(&self.cache[path])
    }

    /// Execute an artifact with f32 tensor inputs `(data, dims)`;
    /// returns the flattened f32 contents of each tuple element.
    /// (The aot pipeline lowers with `return_tuple=True`.)
    pub fn execute_f32(
        &mut self,
        path: &Path,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        self.load(path)?;
        let exe = &self.cache[path];
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            // Outputs may be f32 or i32 (argmin); normalize to f32.
            let v = p
                .to_vec::<f32>()
                .or_else(|_| p.to_vec::<i32>().map(|v| v.into_iter().map(|x| x as f32).collect()))
                .map_err(|e| anyhow!("read output: {e:?}"))?;
            vecs.push(v);
        }
        Ok(vecs)
    }
}

/// Artifact manifest (written by `python -m compile.aot`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<Bucket>,
}

#[derive(Debug, Clone)]
pub struct Bucket {
    pub file: String,
    pub batch: usize,
    pub units: usize,
    pub slots: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut buckets = Vec::new();
        for b in j
            .at("buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| anyhow!("manifest missing buckets"))?
        {
            buckets.push(Bucket {
                file: b
                    .at("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("bucket missing file"))?
                    .to_string(),
                batch: b.at("batch").and_then(|v| v.as_usize()).unwrap_or(0),
                units: b.at("units").and_then(|v| v.as_usize()).unwrap_or(0),
                slots: b.at("slots").and_then(|v| v.as_usize()).unwrap_or(0),
            });
        }
        buckets.sort_by_key(|b| b.units);
        Ok(Manifest {
            dir: dir.to_path_buf(),
            buckets,
        })
    }

    /// Smallest bucket fitting `units` real units and `slots` slots.
    pub fn pick(&self, units: usize, slots: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .find(|b| b.units >= units && b.slots >= slots)
    }

    pub fn path_of(&self, b: &Bucket) -> PathBuf {
        self.dir.join(&b.file)
    }
}

/// Default artifacts directory: `$REPO/artifacts` (overridable for tests
/// via the RSIR_ARTIFACTS env var).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("RSIR_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_loads_and_picks() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let man = Manifest::load(&artifacts_dir()).unwrap();
        assert!(!man.buckets.is_empty());
        let b = man.pick(20, 8).unwrap();
        assert!(b.units >= 20);
        // smallest adequate bucket
        assert_eq!(b.units, 32);
        assert!(man.pick(4096, 8).is_none());
    }

    #[test]
    fn execute_artifact_smoke() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let man = Manifest::load(&artifacts_dir()).unwrap();
        let b = man.pick(8, 8).unwrap().clone();
        let mut rt = Runtime::cpu().unwrap();
        let (bt, m, s) = (b.batch, b.units, b.slots);
        // All-zero instance: cost must be exactly 0 for every candidate.
        let a = vec![0f32; bt * m * s];
        let c = vec![0f32; m * m];
        let d = vec![0f32; s * s];
        let r = vec![0f32; m * 5];
        let caps = vec![0f32; s * 5];
        let lam = vec![1e-4f32];
        let outs = rt
            .execute_f32(
                &man.path_of(&b),
                &[
                    (&a, &[bt as i64, m as i64, s as i64]),
                    (&c, &[m as i64, m as i64]),
                    (&d, &[s as i64, s as i64]),
                    (&r, &[m as i64, 5]),
                    (&caps, &[s as i64, 5]),
                    (&lam, &[1]),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 3); // costs, best_idx, best_cost
        assert_eq!(outs[0].len(), bt);
        assert!(outs[0].iter().all(|&x| x == 0.0));
    }
}

//! Passthrough Pass (§3.3, Fig 10d "auxRAM is bypassed").
//!
//! If netlist analysis shows an interface connects solely and directly to
//! another (a pure feed-through split), the module is bypassed by
//! rerouting connections between the interfaces. The partition pass tags
//! such splits with `passthrough_pairs` metadata; this pass removes the
//! instance and merges each pair's nets, "detaching a wire from one module
//! before connecting it to another" so the two-endpoint invariant holds.

use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use anyhow::Result;

pub struct Passthrough;

impl Pass for Passthrough {
    fn name(&self) -> &'static str {
        "passthrough"
    }

    fn description(&self) -> &'static str {
        "Bypass pure feed-through splits, merging their nets"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        let grouped: Vec<String> = design
            .modules
            .values()
            .filter(|m| m.is_grouped())
            .map(|m| m.name.clone())
            .collect();
        for g in grouped {
            bypass_in(design, &g, ctx)?;
        }
        design.gc();
        // gc removes modules: connectivity caches self-guard, but the
        // cached parents map must not keep listing the removed sites.
        ctx.index.invalidate_parents();
        Ok(())
    }
}

fn bypass_in(design: &mut Design, parent_name: &str, ctx: &mut PassContext) -> Result<()> {
    loop {
        let parent = design.module(parent_name).unwrap();
        // Find a bypassable instance.
        let target = parent.instances().iter().find_map(|inst| {
            let m = design.module(&inst.module_name)?;
            let pairs = m.metadata.get("passthrough_pairs")?.as_arr()?;
            let mut resolved = Vec::new();
            for p in pairs {
                let out_port = p.at("out")?.as_str()?;
                let in_port = p.at("in")?.as_str()?;
                let out_id = inst.connection(out_port)?.as_id()?.to_string();
                let in_id = inst.connection(in_port)?.as_id()?.to_string();
                resolved.push((out_id, in_id));
            }
            Some((inst.instance_name.clone(), resolved))
        });
        let Some((inst_name, pairs)) = target else {
            return Ok(());
        };

        let parent = ctx.index.edit(design, parent_name).unwrap();
        parent
            .instances_mut()
            .retain(|i| i.instance_name != inst_name);
        for (out_id, in_id) in &pairs {
            // Merge nets: prefer keeping a parent-port identifier.
            let out_is_port = parent.port(out_id).is_some();
            let in_is_port = parent.port(in_id).is_some();
            let (keep, drop) = match (out_is_port, in_is_port) {
                (true, true) => {
                    // Two parent ports fed through: cannot merge without an
                    // assign — leave as-is (rare; an exporter-level alias).
                    continue;
                }
                (true, false) => (out_id.clone(), in_id.clone()),
                _ => (in_id.clone(), out_id.clone()),
            };
            // Rewrite all uses of `drop` to `keep`, remove the wire.
            for inst in parent.instances_mut() {
                for c in &mut inst.connections {
                    if let ConnExpr::Id(id) = &mut c.value {
                        if *id == drop {
                            *id = keep.clone();
                        }
                    }
                }
            }
            parent.wires_mut().retain(|w| w.name != drop);
        }
        ctx.log(format!(
            "passthrough: bypassed '{inst_name}' in '{parent_name}' ({} pairs)",
            pairs.len()
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::ir::validate;
    use crate::util::json::{Json, JsonObj};

    /// A -> FT -> B where FT is a tagged feed-through.
    fn design_with_feedthrough() -> Design {
        let a = LeafBuilder::verilog_stub("A")
            .handshake("o", Dir::Out, 32)
            .build();
        let b = LeafBuilder::verilog_stub("B")
            .handshake("i", Dir::In, 32)
            .build();
        let mut ft = LeafBuilder::verilog_stub("FT")
            .port("x", Dir::In, 32)
            .port("x_v", Dir::In, 1)
            .port("x_r", Dir::Out, 1)
            .port("y", Dir::Out, 32)
            .port("y_v", Dir::Out, 1)
            .port("y_r", Dir::In, 1)
            .build();
        let mk = |pairs: &[(&str, &str)]| {
            Json::Arr(
                pairs
                    .iter()
                    .map(|(o, i)| {
                        let mut j = JsonObj::new();
                        j.insert("out", Json::str(*o));
                        j.insert("in", Json::str(*i));
                        Json::Obj(j)
                    })
                    .collect(),
            )
        };
        ft.metadata
            .insert("passthrough_pairs", mk(&[("y", "x"), ("y_v", "x_v"), ("x_r", "y_r")]));
        let top = GroupedBuilder::new("Top")
            .wire("p", 32)
            .wire("p_v", 1)
            .wire("p_r", 1)
            .wire("q", 32)
            .wire("q_v", 1)
            .wire("q_r", 1)
            .inst("a0", "A", &[("o", "p"), ("o_vld", "p_v"), ("o_rdy", "p_r")])
            .inst(
                "ft0",
                "FT",
                &[
                    ("x", "p"),
                    ("x_v", "p_v"),
                    ("x_r", "p_r"),
                    ("y", "q"),
                    ("y_v", "q_v"),
                    ("y_r", "q_r"),
                ],
            )
            .inst("b0", "B", &[("i", "q"), ("i_vld", "q_v"), ("i_rdy", "q_r")])
            .build();
        let mut d = Design::new("Top");
        d.add(a);
        d.add(b);
        d.add(ft);
        d.add(top);
        d
    }

    #[test]
    fn feedthrough_bypassed() {
        let mut d = design_with_feedthrough();
        validate::assert_clean(&d);
        Passthrough.run(&mut d, &mut PassContext::new()).unwrap();
        validate::assert_clean(&d);
        let top = d.module("Top").unwrap();
        assert!(top.instance("ft0").is_none());
        assert_eq!(top.instances().len(), 2);
        // a0 and b0 now share nets directly.
        let a0 = top.instance("a0").unwrap();
        let b0 = top.instance("b0").unwrap();
        assert_eq!(a0.connection("o"), b0.connection("i"));
        assert_eq!(a0.connection("o_rdy"), b0.connection("i_rdy"));
        // FT module garbage-collected.
        assert!(d.module("FT").is_none());
    }

    #[test]
    fn non_tagged_instances_untouched() {
        let mut d = design_with_feedthrough();
        d.module_mut("FT").unwrap().metadata.remove("passthrough_pairs");
        let before = d.clone();
        Passthrough.run(&mut d, &mut PassContext::new()).unwrap();
        assert_eq!(d.module("Top"), before.module("Top"));
    }

    #[test]
    fn wires_pruned_after_bypass() {
        let mut d = design_with_feedthrough();
        Passthrough.run(&mut d, &mut PassContext::new()).unwrap();
        let top = d.module("Top").unwrap();
        // 3 merged wires remain out of 6.
        assert_eq!(top.wires().len(), 3);
    }
}

//! Grouping Pass (§3.3, Fig 10f).
//!
//! Restructures a flat design back into hierarchy: a chosen set of
//! instances inside a grouped module is pulled into a fresh grouped
//! module. Wires fully inside the set move in; wires crossing the
//! boundary become ports of the new group. Used to merge non-pipelinable
//! modules into one partition and to attach floorplan constraints to a
//! whole cluster at once.

use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};

pub struct Group {
    /// Grouped module to operate in (usually the top).
    pub parent: String,
    /// Instances to pull into the new group.
    pub members: Vec<String>,
    /// Name for the new grouped module (instance gets `<name>_inst`).
    pub group_name: String,
}

impl Pass for Group {
    fn name(&self) -> &'static str {
        "group"
    }

    fn description(&self) -> &'static str {
        "Pull instances of a grouped module into a fresh grouped submodule"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        group_instances(design, &self.parent, &self.members, &self.group_name, ctx)
    }
}

pub fn group_instances(
    design: &mut Design,
    parent_name: &str,
    members: &[String],
    group_name: &str,
    ctx: &mut PassContext,
) -> Result<()> {
    let member_set: BTreeSet<&str> = members.iter().map(|s| s.as_str()).collect();
    let parent = design
        .module(parent_name)
        .ok_or_else(|| anyhow!("missing parent '{parent_name}'"))?
        .clone();
    if !parent.is_grouped() {
        bail!("'{parent_name}' is not grouped");
    }
    for m in members {
        if parent.instance(m).is_none() {
            bail!("no instance '{m}' in '{parent_name}'");
        }
    }

    // Classify identifiers by their member/outside endpoints.
    // id -> (member uses, outside uses including parent ports)
    let mut member_use: BTreeMap<String, u32> = BTreeMap::new();
    let mut outside_use: BTreeMap<String, u32> = BTreeMap::new();
    let mut id_width: BTreeMap<String, u32> = BTreeMap::new();
    for w in parent.wires() {
        id_width.insert(w.name.clone(), w.width);
    }
    for p in &parent.ports {
        id_width.insert(p.name.clone(), p.width);
        *outside_use.entry(p.name.clone()).or_default() += 1;
    }
    for inst in parent.instances() {
        let is_member = member_set.contains(inst.instance_name.as_str());
        for c in &inst.connections {
            if let ConnExpr::Id(id) = &c.value {
                if is_member {
                    *member_use.entry(id.clone()).or_default() += 1;
                } else {
                    *outside_use.entry(id.clone()).or_default() += 1;
                }
            }
        }
    }

    // Direction of a boundary port: determined by the member-side port dir.
    let mut boundary_dir: BTreeMap<String, Dir> = BTreeMap::new();
    for inst in parent.instances() {
        if !member_set.contains(inst.instance_name.as_str()) {
            continue;
        }
        let Some(target) = design.module(&inst.module_name) else {
            continue;
        };
        for c in &inst.connections {
            if let ConnExpr::Id(id) = &c.value {
                if member_use.get(id).copied().unwrap_or(0) > 0
                    && outside_use.get(id).copied().unwrap_or(0) > 0
                {
                    if let Some(p) = target.port(&c.port) {
                        boundary_dir.insert(id.clone(), p.dir);
                    }
                }
            }
        }
    }

    let mut group = Module::grouped(group_name);
    // Boundary identifiers become group ports (same name inside and out).
    for (id, dir) in &boundary_dir {
        group.ports.push(Port::new(
            id,
            *dir,
            id_width.get(id).copied().unwrap_or(1),
        ));
        // Clock/reset broadcast coverage transfers from the parent so the
        // fan-out exemption holds inside the group.
        if let Some(iface) = parent.interface_of(id) {
            if matches!(iface, Interface::Clock { .. } | Interface::Reset { .. })
                && group.interface_of(id).is_none()
            {
                group.interfaces.push(iface.clone());
            }
        }
    }
    // Internal wires (member-only) move into the group.
    for w in parent.wires() {
        let internal = member_use.get(&w.name).copied().unwrap_or(0) > 0
            && outside_use.get(&w.name).copied().unwrap_or(0) == 0;
        if internal {
            group.wires_mut().push(w.clone());
        }
    }
    // Move member instances.
    for inst in parent.instances() {
        if member_set.contains(inst.instance_name.as_str()) {
            group.instances_mut().push(inst.clone());
        }
    }

    // Rewrite the parent (through the index: only its cache dirties).
    let group_mod_name = design.fresh_module_name(group_name);
    group.name = group_mod_name.clone();
    let parent_mut = ctx.index.edit(design, parent_name).unwrap();
    parent_mut
        .instances_mut()
        .retain(|i| !member_set.contains(i.instance_name.as_str()));
    parent_mut.wires_mut().retain(|w| {
        !(member_use.get(&w.name).copied().unwrap_or(0) > 0
            && outside_use.get(&w.name).copied().unwrap_or(0) == 0)
    });
    let mut ginst = Instance::new(format!("{group_mod_name}_inst"), &group_mod_name);
    for (id, _) in &boundary_dir {
        ginst.connect(id, ConnExpr::id(id));
    }
    parent_mut.instances_mut().push(ginst);

    for m in members {
        ctx.namemap
            .record("group", m, &format!("{group_mod_name}_inst/{m}"));
    }
    ctx.log(format!(
        "group: {} instances of '{parent_name}' into '{group_mod_name}'",
        members.len()
    ));
    ctx.index.touch(&group_mod_name);
    design.add(group);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::ir::validate;
    use crate::passes::flatten::Flatten;

    fn chain3() -> Design {
        let leaf = |name: &str| {
            LeafBuilder::verilog_stub(name)
                .handshake("i", Dir::In, 8)
                .handshake("o", Dir::Out, 8)
                .build()
        };
        let mut d = Design::new("Top");
        d.add(leaf("A"));
        d.add(leaf("B"));
        d.add(leaf("C"));
        let top = GroupedBuilder::new("Top")
            .port("in", Dir::In, 8)
            .port("in_vld", Dir::In, 1)
            .port("in_rdy", Dir::Out, 1)
            .port("out", Dir::Out, 8)
            .port("out_vld", Dir::Out, 1)
            .port("out_rdy", Dir::In, 1)
            .wire("x", 8)
            .wire("x_vld", 1)
            .wire("x_rdy", 1)
            .wire("y", 8)
            .wire("y_vld", 1)
            .wire("y_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("i", "in"),
                    ("i_vld", "in_vld"),
                    ("i_rdy", "in_rdy"),
                    ("o", "x"),
                    ("o_vld", "x_vld"),
                    ("o_rdy", "x_rdy"),
                ],
            )
            .inst(
                "b0",
                "B",
                &[
                    ("i", "x"),
                    ("i_vld", "x_vld"),
                    ("i_rdy", "x_rdy"),
                    ("o", "y"),
                    ("o_vld", "y_vld"),
                    ("o_rdy", "y_rdy"),
                ],
            )
            .inst(
                "c0",
                "C",
                &[
                    ("i", "y"),
                    ("i_vld", "y_vld"),
                    ("i_rdy", "y_rdy"),
                    ("o", "out"),
                    ("o_vld", "out_vld"),
                    ("o_rdy", "out_rdy"),
                ],
            )
            .build();
        d.add(top);
        d
    }

    #[test]
    fn group_two_of_three() {
        let mut d = chain3();
        validate::assert_clean(&d);
        group_instances(
            &mut d,
            "Top",
            &["b0".into(), "c0".into()],
            "BC",
            &mut PassContext::new(),
        )
        .unwrap();
        validate::assert_clean(&d);
        let top = d.module("Top").unwrap();
        assert_eq!(top.instances().len(), 2); // a0 + BC_inst
        let bc = d.module("BC").unwrap();
        assert_eq!(bc.instances().len(), 2);
        // x* cross the boundary -> ports; y* internal -> wires.
        assert!(bc.port("x").is_some());
        assert_eq!(bc.port("x").unwrap().dir, Dir::In);
        assert!(bc.wires().iter().any(|w| w.name == "y"));
        assert!(!top.wires().iter().any(|w| w.name == "y"));
    }

    #[test]
    fn group_then_flatten_roundtrip() {
        let mut d = chain3();
        let orig = d.clone();
        group_instances(
            &mut d,
            "Top",
            &["b0".into(), "c0".into()],
            "BC",
            &mut PassContext::new(),
        )
        .unwrap();
        Flatten.run(&mut d, &mut PassContext::new()).unwrap();
        validate::assert_clean(&d);
        // Same leaf count and edge structure as the original.
        let top = d.module("Top").unwrap();
        assert_eq!(top.instances().len(), orig.module("Top").unwrap().instances().len());
        let g_orig = crate::ir::graph::BlockGraph::build(orig.module("Top").unwrap());
        let g_new = crate::ir::graph::BlockGraph::build(top);
        // Compare inter-instance edge weights modulo renaming.
        let w = |g: &crate::ir::graph::BlockGraph| -> Vec<u64> {
            let mut v: Vec<u64> = g.instance_edges(&[]).iter().map(|e| e.2).collect();
            v.sort();
            v
        };
        assert_eq!(w(&g_orig), w(&g_new));
    }

    #[test]
    fn group_port_dir_for_output_boundary() {
        let mut d = chain3();
        group_instances(
            &mut d,
            "Top",
            &["a0".into()],
            "GA",
            &mut PassContext::new(),
        )
        .unwrap();
        let ga = d.module("GA").unwrap();
        assert_eq!(ga.port("x").unwrap().dir, Dir::Out);
        assert_eq!(ga.port("x_rdy").unwrap().dir, Dir::In);
        validate::assert_clean(&d);
    }

    #[test]
    fn rejects_unknown_member() {
        let mut d = chain3();
        assert!(group_instances(
            &mut d,
            "Top",
            &["ghost".into()],
            "G",
            &mut PassContext::new()
        )
        .is_err());
    }
}

//! Flattening Pass (§3.3, Fig 10e).
//!
//! ILP-based floorplanning wants a flat module graph, not a hypergraph of
//! nested hierarchies. This pass recursively merges grouped submodules of
//! the top module into it: wires are consolidated (child wires renamed
//! `<inst>__<wire>`), child instances are re-parented, and child port
//! connections are re-established through the parent's identifiers.
//! Leaf modules are untouched; "Without this pass, [Layer_1 and Layer_2]
//! would have to be grouped into a single partition".

use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

pub struct Flatten;

impl Pass for Flatten {
    fn name(&self) -> &'static str {
        "flatten"
    }

    fn description(&self) -> &'static str {
        "Recursively inline grouped submodules into the top module"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        flatten_top(design, ctx)
    }
}

pub fn flatten_top(design: &mut Design, ctx: &mut PassContext) -> Result<()> {
    loop {
        let top = design
            .module(&design.top)
            .ok_or_else(|| anyhow!("missing top"))?;
        if !top.is_grouped() {
            return Ok(()); // leaf top: nothing to flatten
        }
        let target = top
            .instances()
            .iter()
            .find(|i| {
                design
                    .module(&i.module_name)
                    .map(|m| m.is_grouped())
                    .unwrap_or(false)
            })
            .map(|i| i.instance_name.clone());
        let Some(inst_name) = target else {
            design.gc();
            // gc removes modules: the cached parents map must not keep
            // listing the removed instantiation sites.
            ctx.index.invalidate_parents();
            return Ok(());
        };
        inline_instance(design, &design.top.clone(), &inst_name, ctx)?;
    }
}

/// Inline one grouped-module instance `inst_name` into grouped `parent`.
pub fn inline_instance(
    design: &mut Design,
    parent_name: &str,
    inst_name: &str,
    ctx: &mut PassContext,
) -> Result<()> {
    let parent = design
        .module(parent_name)
        .ok_or_else(|| anyhow!("missing parent '{parent_name}'"))?;
    let inst = parent
        .instance(inst_name)
        .ok_or_else(|| anyhow!("no instance '{inst_name}' in '{parent_name}'"))?
        .clone();
    let child = design
        .module(&inst.module_name)
        .ok_or_else(|| anyhow!("missing module '{}'", inst.module_name))?
        .clone();
    if !child.is_grouped() {
        return Ok(());
    }

    // Alias: child port -> parent connection expression.
    let mut alias: BTreeMap<String, ConnExpr> = BTreeMap::new();
    for p in &child.ports {
        let v = inst
            .connection(&p.name)
            .cloned()
            .unwrap_or(ConnExpr::Open);
        alias.insert(p.name.clone(), v);
    }

    // Inlining rewrites only the parent; edit through the index so just
    // its connectivity cache is dirtied.
    let parent = ctx.index.edit(design, parent_name).unwrap();
    // Remove the instance being inlined.
    let idx = parent
        .instances()
        .iter()
        .position(|i| i.instance_name == inst_name)
        .unwrap();
    parent.instances_mut().remove(idx);

    // Existing identifiers, to avoid collisions for imported wires.
    let mut used: std::collections::BTreeSet<String> = parent
        .wires()
        .iter()
        .map(|w| w.name.clone())
        .chain(parent.ports.iter().map(|p| p.name.clone()))
        .collect();

    // Import child wires under a prefixed name.
    let mut wire_rename: BTreeMap<String, String> = BTreeMap::new();
    for w in child.wires() {
        let mut nn = format!("{inst_name}__{}", w.name);
        while used.contains(&nn) {
            nn.push('_');
        }
        used.insert(nn.clone());
        wire_rename.insert(w.name.clone(), nn.clone());
        parent.wires_mut().push(Wire {
            name: nn,
            width: w.width,
        });
        ctx.namemap
            .record("flatten", &format!("{}/{}", inst.module_name, w.name), wire_rename[&w.name].as_str());
    }

    // Existing instance names.
    let mut inst_used: std::collections::BTreeSet<String> = parent
        .instances()
        .iter()
        .map(|i| i.instance_name.clone())
        .collect();

    // Re-parent child instances.
    for ci in child.instances() {
        let mut nn = format!("{inst_name}__{}", ci.instance_name);
        while inst_used.contains(&nn) {
            nn.push('_');
        }
        inst_used.insert(nn.clone());
        let mut new_inst = Instance::new(&nn, &ci.module_name);
        new_inst.metadata = ci.metadata.clone();
        for conn in &ci.connections {
            let v = match &conn.value {
                ConnExpr::Id(id) => {
                    if let Some(renamed) = wire_rename.get(id) {
                        ConnExpr::Id(renamed.clone())
                    } else if let Some(parent_expr) = alias.get(id) {
                        parent_expr.clone()
                    } else {
                        // Identifier must be a child wire or port by DRC.
                        ConnExpr::Id(id.clone())
                    }
                }
                other => other.clone(),
            };
            new_inst.connections.push(Connection {
                port: conn.port.clone(),
                value: v,
            });
        }
        ctx.namemap.record(
            "flatten",
            &format!("{inst_name}/{}", ci.instance_name),
            &nn,
        );
        parent.instances_mut().push(new_inst);
    }

    ctx.log(format!(
        "flatten: inlined '{inst_name}' ({}) into '{parent_name}'",
        inst.module_name
    ));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::ir::validate;

    /// Top { a0: A, mid: Mid { l1: Leaf, l2: Leaf } } with a handshake
    /// chain a0 -> l1 -> l2 where the l1→l2 hop is internal to Mid.
    fn nested() -> Design {
        let leaf = |name: &str| {
            LeafBuilder::verilog_stub(name)
                .clk_rst()
                .handshake("i", Dir::In, 16)
                .handshake("o", Dir::Out, 16)
                .build()
        };
        let mut d = Design::new("Top");
        d.add(leaf("A"));
        d.add(leaf("L1"));
        d.add(leaf("L2"));
        let mid = GroupedBuilder::new("Mid")
            .port("i", Dir::In, 16)
            .port("i_vld", Dir::In, 1)
            .port("i_rdy", Dir::Out, 1)
            .port("o", Dir::Out, 16)
            .port("o_vld", Dir::Out, 1)
            .port("o_rdy", Dir::In, 1)
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .wire("m", 16)
            .wire("m_vld", 1)
            .wire("m_rdy", 1)
            .inst(
                "l1",
                "L1",
                &[
                    ("i", "i"),
                    ("i_vld", "i_vld"),
                    ("i_rdy", "i_rdy"),
                    ("o", "m"),
                    ("o_vld", "m_vld"),
                    ("o_rdy", "m_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .inst(
                "l2",
                "L2",
                &[
                    ("i", "m"),
                    ("i_vld", "m_vld"),
                    ("i_rdy", "m_rdy"),
                    ("o", "o"),
                    ("o_vld", "o_vld"),
                    ("o_rdy", "o_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        d.add(mid);
        let top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .port("out", Dir::Out, 16)
            .port("out_vld", Dir::Out, 1)
            .port("out_rdy", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .iface(Interface::Handshake {
                name: "out".into(),
                data: vec!["out".into()],
                valid: "out_vld".into(),
                ready: "out_rdy".into(),
                clk: Some("ap_clk".into()),
            })
            .wire("t", 16)
            .wire("t_vld", 1)
            .wire("t_rdy", 1)
            .wire("a_i", 16)
            .wire("a_i_vld", 1)
            .wire("a_i_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("i", "a_i"),
                    ("i_vld", "a_i_vld"),
                    ("i_rdy", "a_i_rdy"),
                    ("o", "t"),
                    ("o_vld", "t_vld"),
                    ("o_rdy", "t_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .inst(
                "mid",
                "Mid",
                &[
                    ("i", "t"),
                    ("i_vld", "t_vld"),
                    ("i_rdy", "t_rdy"),
                    ("o", "out"),
                    ("o_vld", "out_vld"),
                    ("o_rdy", "out_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        d.add(top);
        d
    }

    #[test]
    fn flatten_inlines_everything() {
        let mut d = nested();
        // a_i* dangle (A's input unconnected upstream) — wire them to ports
        // to keep DRC clean for this test.
        {
            let top = d.module_mut("Top").unwrap();
            top.ports.push(Port::new("a_in", Dir::In, 16));
            top.ports.push(Port::new("a_in_vld", Dir::In, 1));
            top.ports.push(Port::new("a_in_rdy", Dir::Out, 1));
            top.wires_mut().retain(|w| !w.name.starts_with("a_i"));
            let a0 = top.instances_mut().iter_mut().find(|i| i.instance_name == "a0").unwrap();
            for (p, v) in [("i", "a_in"), ("i_vld", "a_in_vld"), ("i_rdy", "a_in_rdy")] {
                *a0.connection_mut(p).unwrap() = ConnExpr::id(v);
            }
        }
        validate::assert_clean(&d);
        let mut ctx = PassContext::new();
        Flatten.run(&mut d, &mut ctx).unwrap();
        let top = d.module("Top").unwrap();
        assert_eq!(top.instances().len(), 3); // a0, mid__l1, mid__l2
        assert!(top.instance("mid__l1").is_some());
        assert!(d.module("Mid").is_none(), "gc should drop Mid");
        validate::assert_clean(&d);
    }

    #[test]
    fn internal_wire_renamed_and_connected() {
        let mut d = nested();
        {
            // same DRC fixup as above
            let top = d.module_mut("Top").unwrap();
            top.ports.push(Port::new("a_in", Dir::In, 16));
            top.ports.push(Port::new("a_in_vld", Dir::In, 1));
            top.ports.push(Port::new("a_in_rdy", Dir::Out, 1));
            top.wires_mut().retain(|w| !w.name.starts_with("a_i"));
            let a0 = top.instances_mut().iter_mut().find(|i| i.instance_name == "a0").unwrap();
            for (p, v) in [("i", "a_in"), ("i_vld", "a_in_vld"), ("i_rdy", "a_in_rdy")] {
                *a0.connection_mut(p).unwrap() = ConnExpr::id(v);
            }
        }
        Flatten.run(&mut d, &mut PassContext::new()).unwrap();
        let top = d.module("Top").unwrap();
        assert!(top.wires().iter().any(|w| w.name == "mid__m"));
        let l1 = top.instance("mid__l1").unwrap();
        assert_eq!(l1.connection("o"), Some(&ConnExpr::id("mid__m")));
        // Boundary connection rewired to parent wire t.
        assert_eq!(l1.connection("i"), Some(&ConnExpr::id("t")));
        // Parent port of Mid mapped through to Top's port.
        let l2 = top.instance("mid__l2").unwrap();
        assert_eq!(l2.connection("o"), Some(&ConnExpr::id("out")));
    }

    #[test]
    fn flatten_is_idempotent() {
        let mut d = nested();
        {
            let top = d.module_mut("Top").unwrap();
            top.ports.push(Port::new("a_in", Dir::In, 16));
            top.ports.push(Port::new("a_in_vld", Dir::In, 1));
            top.ports.push(Port::new("a_in_rdy", Dir::Out, 1));
            top.wires_mut().retain(|w| !w.name.starts_with("a_i"));
            let a0 = top.instances_mut().iter_mut().find(|i| i.instance_name == "a0").unwrap();
            for (p, v) in [("i", "a_in"), ("i_vld", "a_in_vld"), ("i_rdy", "a_in_rdy")] {
                *a0.connection_mut(p).unwrap() = ConnExpr::id(v);
            }
        }
        let mut ctx = PassContext::new();
        Flatten.run(&mut d, &mut ctx).unwrap();
        let once = d.clone();
        Flatten.run(&mut d, &mut ctx).unwrap();
        assert_eq!(d, once);
    }

    #[test]
    fn namemap_traces_inlined_instances() {
        let mut d = nested();
        {
            let top = d.module_mut("Top").unwrap();
            top.ports.push(Port::new("a_in", Dir::In, 16));
            top.ports.push(Port::new("a_in_vld", Dir::In, 1));
            top.ports.push(Port::new("a_in_rdy", Dir::Out, 1));
            top.wires_mut().retain(|w| !w.name.starts_with("a_i"));
            let a0 = top.instances_mut().iter_mut().find(|i| i.instance_name == "a0").unwrap();
            for (p, v) in [("i", "a_in"), ("i_vld", "a_in_vld"), ("i_rdy", "a_in_rdy")] {
                *a0.connection_mut(p).unwrap() = ConnExpr::id(v);
            }
        }
        let mut ctx = PassContext::new();
        Flatten.run(&mut d, &mut ctx).unwrap();
        assert_eq!(ctx.namemap.trace("mid__l1"), "mid/l1");
    }
}

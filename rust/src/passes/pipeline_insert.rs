//! Pipeline insertion — the Wrapping Pass (§3.3) applied to interconnect
//! synthesis (§3.4 stage 4): break a latency-tolerant channel between two
//! instances with a relay station (handshake) or an FF chain
//! (feedforward), then rely on flattening to elevate the helper.
//!
//! Operates on a flat grouped module: given the source instance and its
//! handshake *output* interface, the three wires (data/valid/ready) are cut
//! and a pipeline element is inserted in between, optionally carrying a
//! `floorplan` slot assignment for each stage.

use crate::interconnect;
use crate::ir::core::*;
use crate::ir::graph::GraphError;
use crate::ir::index::{ConnEndpoint, DesignIndex, InstId};
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use anyhow::{anyhow, bail, Result};

/// Pass form of [`insert_relay_station`], operating on the design's top
/// module: registry name `relay-insert`, argument
/// `SRC_INST/IFACE[/STAGES]`.
pub struct InsertRelayStation {
    /// Instance inside the top module driving the channel.
    pub src_inst: String,
    /// Output handshake interface of that instance to cut.
    pub iface: String,
    pub stages: u32,
    /// Optional pblock to attach as `floorplan` metadata.
    pub slot: Option<String>,
}

impl Pass for InsertRelayStation {
    fn name(&self) -> &'static str {
        "relay-insert"
    }

    fn description(&self) -> &'static str {
        "Insert a relay station on a handshake channel of the flat top"
    }

    fn index_policy(&self) -> IndexPolicy {
        // All mutations go through ctx.index.edit / touch.
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        let top = design.top.clone();
        insert_relay_station(
            design,
            &top,
            &self.src_inst,
            &self.iface,
            self.stages,
            self.slot.as_deref(),
            ctx,
        )?;
        Ok(())
    }
}

/// Insert a relay station on the handshake interface `iface_name` *driven
/// by* instance `src_inst` inside grouped module `parent`. Returns the
/// inserted instance name. `slot` attaches floorplan metadata.
pub fn insert_relay_station(
    design: &mut Design,
    parent_name: &str,
    src_inst: &str,
    iface_name: &str,
    stages: u32,
    slot: Option<&str>,
    ctx: &mut PassContext,
) -> Result<String> {
    let parent = design
        .module(parent_name)
        .ok_or_else(|| anyhow!("missing parent '{parent_name}'"))?;
    let inst = parent
        .instance(src_inst)
        .ok_or_else(|| anyhow!("no instance '{src_inst}'"))?
        .clone();
    let src_mod = design
        .module(&inst.module_name)
        .ok_or_else(|| anyhow!("missing module '{}'", inst.module_name))?;
    // Several interfaces may share a name (pragma fallback bundles);
    // pick the *output* handshake with this name.
    let iface = src_mod
        .interfaces
        .iter()
        .filter(|i| i.name() == iface_name)
        .find(|i| match i {
            Interface::Handshake { valid, .. } => {
                src_mod.port(valid).map(|p| p.dir) == Some(Dir::Out)
            }
            _ => false,
        })
        .ok_or_else(|| {
            anyhow!(
                "interface '{iface_name}' on '{src_inst}' is not an output handshake"
            )
        })?
        .clone();
    let Interface::Handshake {
        data,
        valid,
        ready,
        ..
    } = &iface
    else {
        unreachable!()
    };
    let width: u32 = data
        .iter()
        .map(|d| src_mod.port(d).map(|p| p.width).unwrap_or(0))
        .sum();

    // The identifiers currently carrying this channel.
    let id_of = |port: &str| -> Result<String> {
        match inst.connection(port) {
            Some(ConnExpr::Id(id)) => Ok(id.clone()),
            other => bail!("port '{port}' of '{src_inst}' not an identifier: {other:?}"),
        }
    };
    // Concatenated data is only supported for single-port data bundles
    // (the general case would need a packer aux; HLS channels are 1-port).
    if data.len() != 1 {
        bail!("multi-port data bundles not supported for pipelining yet");
    }
    let data_id = id_of(&data[0])?;
    let valid_id = id_of(valid)?;
    let ready_id = id_of(ready)?;

    // Ensure the relay-station module exists.
    let rs = interconnect::relay_station(width, stages);
    let rs_name = rs.name.clone();
    if design.module(&rs_name).is_none() {
        design.add(rs);
        ctx.index.touch(&rs_name);
    }

    // New wires from relay station to the old consumer side; the old wires
    // now terminate at the relay-station input. (We rewire the *source*
    // instance to fresh wires and feed the relay from those, keeping the
    // consumer untouched.) Editing through the index marks only the
    // parent's connectivity cache dirty.
    let parent = ctx.index.edit(design, parent_name).unwrap();
    let rs_inst_name = {
        let mut base = format!("rs_{src_inst}_{iface_name}");
        let mut k = 0;
        while parent.instance(&base).is_some() {
            k += 1;
            base = format!("rs_{src_inst}_{iface_name}_{k}");
        }
        base
    };
    let fresh = |parent: &mut Module, base: &str, width: u32| -> String {
        let mut name = base.to_string();
        while parent.wires().iter().any(|w| w.name == name)
            || parent.port(&name).is_some()
        {
            name.push('_');
        }
        parent.wires_mut().push(Wire {
            name: name.clone(),
            width,
        });
        name
    };
    let nd = fresh(parent, &format!("{rs_inst_name}__d"), width);
    let nv = fresh(parent, &format!("{rs_inst_name}__v"), 1);
    let nr = fresh(parent, &format!("{rs_inst_name}__r"), 1);

    // Rewire source instance outputs to the fresh wires.
    {
        let src = parent
            .instances_mut()
            .iter_mut()
            .find(|i| i.instance_name == src_inst)
            .unwrap();
        *src.connection_mut(&data[0]).unwrap() = ConnExpr::id(&nd);
        *src.connection_mut(valid).unwrap() = ConnExpr::id(&nv);
        *src.connection_mut(ready).unwrap() = ConnExpr::id(&nr);
    }

    // Relay instance: input from fresh wires, output to the old wires.
    let mut rs_inst = Instance::new(&rs_inst_name, &rs_name);
    rs_inst.connect("i", ConnExpr::id(&nd));
    rs_inst.connect("i_vld", ConnExpr::id(&nv));
    rs_inst.connect("i_rdy", ConnExpr::id(&nr));
    rs_inst.connect("o", ConnExpr::id(&data_id));
    rs_inst.connect("o_vld", ConnExpr::id(&valid_id));
    rs_inst.connect("o_rdy", ConnExpr::id(&ready_id));
    // Clock/reset broadcast.
    let (clk, rst) = clock_reset_ids(parent);
    if let Some(c) = clk {
        rs_inst.connect("ap_clk", ConnExpr::id(c));
    }
    match rst {
        Some(r) => rs_inst.connect("ap_rst_n", ConnExpr::id(r)),
        None => rs_inst.connect("ap_rst_n", ConnExpr::Const { width: 1, value: 1 }),
    }
    if let Some(s) = slot {
        rs_inst
            .metadata
            .insert("floorplan", crate::util::json::Json::str(s));
    }
    parent.instances_mut().push(rs_inst);
    ctx.namemap.record(
        "pipeline-insert",
        &format!("{src_inst}.{iface_name}"),
        &rs_inst_name,
    );
    ctx.log(format!(
        "pipeline: relay station '{rs_inst_name}' ({width}b × {stages} stages) on {src_inst}.{iface_name}"
    ));
    Ok(rs_inst_name)
}

/// Clock / active-low reset identifiers of a grouped module, if declared.
fn clock_reset_ids(m: &Module) -> (Option<&str>, Option<&str>) {
    let mut clk = None;
    let mut rst = None;
    for i in &m.interfaces {
        match i {
            Interface::Clock { port } => clk = Some(port.as_str()),
            Interface::Reset { port, .. } => rst = Some(port.as_str()),
            _ => {}
        }
    }
    (clk, rst)
}

/// Count pipeline stages needed for a slot pair: one relay station per die
/// crossing plus one per two plain hops (AutoBridge's rule of thumb).
pub fn stages_for_distance(manhattan: usize, die_crossings: usize) -> u32 {
    (die_crossings as u32) + (manhattan.saturating_sub(die_crossings) as u32).div_ceil(2)
}

/// All pipelinable channels of a flat grouped module:
/// (src_inst, iface_name, dst_inst, width). Connectivity comes from the
/// cached index; a leaf parent yields a typed [`GraphError`] for the
/// caller to route into a diagnostic (historically this panicked).
pub fn pipelinable_channels(
    design: &Design,
    parent_name: &str,
    index: &mut DesignIndex,
) -> Result<Vec<(String, String, String, u32)>, GraphError> {
    let Some(parent) = design.module(parent_name) else {
        return Ok(Vec::new());
    };
    let (conn, interner) = index.conn(design, parent_name)?;
    let mut out = Vec::new();
    for (ii, inst) in parent.instances().iter().enumerate() {
        let Some(m) = design.module(&inst.module_name) else {
            continue;
        };
        for iface in &m.interfaces {
            let Interface::Handshake { name, data, valid, .. } = iface else {
                continue;
            };
            if m.port(valid).map(|p| p.dir) != Some(Dir::Out) {
                continue;
            }
            // Find consumer through the valid wire.
            let Some(ConnExpr::Id(vid)) = inst.connection(valid) else {
                continue;
            };
            let Some(net) = conn.net_id(interner, vid) else {
                continue;
            };
            let Some(valid_sym) = interner.get(valid) else {
                continue;
            };
            let this = ConnEndpoint::Inst {
                inst: InstId(ii as u32),
                port: valid_sym,
            };
            let Some(opp) = conn.opposite(net, &this) else {
                continue;
            };
            let dst = match opp {
                ConnEndpoint::Inst { inst, .. } => {
                    let name = conn.insts[inst.as_usize()].name;
                    interner.resolve(name).to_string()
                }
                ConnEndpoint::Parent { .. } => continue,
            };
            let width: u32 = data
                .iter()
                .map(|d| m.port(d).map(|p| p.width).unwrap_or(0))
                .sum();
            out.push((inst.instance_name.clone(), name.clone(), dst, width));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::ir::validate;

    fn two_stage() -> Design {
        let a = LeafBuilder::verilog_stub("A")
            .clk_rst()
            .handshake("o", Dir::Out, 64)
            .build();
        let b = LeafBuilder::verilog_stub("B")
            .clk_rst()
            .handshake("i", Dir::In, 64)
            .build();
        let top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .wire("d", 64)
            .wire("d_vld", 1)
            .wire("d_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("o", "d"),
                    ("o_vld", "d_vld"),
                    ("o_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .inst(
                "b0",
                "B",
                &[
                    ("i", "d"),
                    ("i_vld", "d_vld"),
                    ("i_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        let mut d = Design::new("Top");
        d.add(a);
        d.add(b);
        d.add(top);
        d
    }

    #[test]
    fn insert_preserves_drc() {
        let mut d = two_stage();
        validate::assert_clean(&d);
        let rs = insert_relay_station(
            &mut d,
            "Top",
            "a0",
            "o",
            2,
            Some("SLOT_X0Y1"),
            &mut PassContext::new(),
        )
        .unwrap();
        validate::assert_clean(&d);
        let top = d.module("Top").unwrap();
        assert_eq!(top.instances().len(), 3);
        let rsi = top.instance(&rs).unwrap();
        assert_eq!(
            rsi.metadata.get("floorplan").unwrap().as_str(),
            Some("SLOT_X0Y1")
        );
        // Consumer untouched.
        let b0 = top.instance("b0").unwrap();
        assert_eq!(b0.connection("i"), Some(&ConnExpr::id("d")));
        // Source rewired to fresh wires.
        let a0 = top.instance("a0").unwrap();
        assert_ne!(a0.connection("o"), Some(&ConnExpr::id("d")));
    }

    #[test]
    fn inserted_module_is_pipeline_element() {
        let mut d = two_stage();
        insert_relay_station(&mut d, "Top", "a0", "o", 1, None, &mut PassContext::new())
            .unwrap();
        let rs_mod = d.module("rs_w64_s1").unwrap();
        assert!(rs_mod
            .metadata
            .get("pipeline_element")
            .and_then(|v| v.as_bool())
            .unwrap());
    }

    #[test]
    fn double_insertion_chains() {
        let mut d = two_stage();
        let mut ctx = PassContext::new();
        insert_relay_station(&mut d, "Top", "a0", "o", 1, None, &mut ctx).unwrap();
        // Insert another stage after the first relay station.
        insert_relay_station(&mut d, "Top", "rs_a0_o", "o", 1, None, &mut ctx).unwrap();
        validate::assert_clean(&d);
        assert_eq!(d.module("Top").unwrap().instances().len(), 4);
    }

    #[test]
    fn channels_detected() {
        let d = two_stage();
        let mut index = crate::ir::index::DesignIndex::for_design(&d);
        let ch = pipelinable_channels(&d, "Top", &mut index).unwrap();
        assert_eq!(ch.len(), 1);
        assert_eq!(ch[0], ("a0".into(), "o".into(), "b0".into(), 64));
    }

    #[test]
    fn leaf_parent_is_typed_error_not_panic() {
        let mut d = Design::new("OnlyLeaf");
        d.add(Module::leaf("OnlyLeaf", SourceFormat::Verilog, ""));
        let mut index = crate::ir::index::DesignIndex::for_design(&d);
        let err = pipelinable_channels(&d, "OnlyLeaf", &mut index).unwrap_err();
        assert!(matches!(err, GraphError::Leaf { .. }));
        // An unknown parent is simply empty, as before.
        let ch = pipelinable_channels(&d, "Ghost", &mut index).unwrap();
        assert!(ch.is_empty());
    }

    #[test]
    fn stage_heuristic() {
        assert_eq!(stages_for_distance(0, 0), 0);
        assert_eq!(stages_for_distance(1, 1), 1);
        assert_eq!(stages_for_distance(3, 1), 2);
        assert_eq!(stages_for_distance(4, 2), 3);
    }

    #[test]
    fn rejects_input_handshake() {
        let mut d = two_stage();
        let err = insert_relay_station(&mut d, "Top", "b0", "i", 1, None, &mut PassContext::new())
            .unwrap_err();
        assert!(err.to_string().contains("not an output handshake"));
    }
}

//! Interface Inference Pass (§3.3, Fig 10c).
//!
//! Modules lacking interface information (above all the aux modules minted
//! by the hierarchy rebuild) get interfaces transferred from the modules
//! they connect to: "for aux modules created during the hierarchy rebuild
//! pass, the interface inferencer defines their interfaces by transferring
//! information from the aux's sibling modules".
//!
//! For every wire `A.pa ↔ B.pb` inside a grouped module where `A`'s module
//! covers `pa` with an interface and `B`'s module has nothing covering
//! `pb`, the mirrored interface is created on `B`'s module (handshake
//! roles preserved, direction implicit in the ports). Parent ports take
//! part through the grouped module's own interfaces.

use crate::ir::core::*;
use crate::ir::index::ConnEndpoint;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use anyhow::Result;
use std::collections::BTreeMap;

pub struct InterfaceInference;

impl Pass for InterfaceInference {
    fn name(&self) -> &'static str {
        "iface-infer"
    }

    fn description(&self) -> &'static str {
        "Transfer interfaces onto modules lacking them from their siblings"
    }

    fn index_policy(&self) -> IndexPolicy {
        // Reads connectivity from the cached index; only mutates
        // interface lists, which the index does not cache.
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        // Iterate to a fixpoint: inference can cascade through aux chains.
        for _ in 0..design.modules.len() + 1 {
            if infer_once(design, ctx)? == 0 {
                break;
            }
        }
        Ok(())
    }
}

fn infer_once(design: &mut Design, ctx: &mut PassContext) -> Result<usize> {
    let grouped: Vec<String> = design
        .modules
        .values()
        .filter(|m| m.is_grouped())
        .map(|m| m.name.clone())
        .collect();
    let mut created = 0usize;
    for gname in grouped {
        created += infer_in_grouped(design, &gname, ctx)?;
    }
    Ok(created)
}

/// Where a module port maps to on the "other side" of the parent's wires.
#[derive(Debug, Clone)]
struct PeerPort {
    /// Instance name inside the grouped module ("" = the parent itself).
    peer_holder: String,
    peer_module: String,
    peer_port: String,
}

fn infer_in_grouped(design: &mut Design, gname: &str, ctx: &mut PassContext) -> Result<usize> {
    // For each (holder, port), resolve the opposite endpoint through the
    // cached connectivity index. holder "" = parent.
    let mut peers: BTreeMap<(String, String), PeerPort> = BTreeMap::new();
    {
        let (conn, interner) = ctx.index.conn(design, gname)?;
        let resolve = |e: &ConnEndpoint| -> (String, String, String) {
            match e {
                ConnEndpoint::Parent { port } => {
                    let p = interner.resolve(conn.ports[port.as_usize()].name);
                    ("".to_string(), gname.to_string(), p.to_string())
                }
                ConnEndpoint::Inst { inst, port } => {
                    let i = &conn.insts[inst.as_usize()];
                    (
                        interner.resolve(i.name).to_string(),
                        interner.resolve(i.module).to_string(),
                        interner.resolve(*port).to_string(),
                    )
                }
            }
        };
        for info in &conn.nets {
            if info.endpoints.len() != 2 {
                continue;
            }
            let a = resolve(&info.endpoints[0]);
            let b = resolve(&info.endpoints[1]);
            peers.insert(
                (a.0.clone(), a.2.clone()),
                PeerPort {
                    peer_holder: b.0.clone(),
                    peer_module: b.1.clone(),
                    peer_port: b.2.clone(),
                },
            );
            peers.insert(
                (b.0, b.2),
                PeerPort {
                    peer_holder: a.0,
                    peer_module: a.1,
                    peer_port: a.2,
                },
            );
        }
    }

    // Collect candidate transfers: for each holder side with an interface,
    // mirror onto peers lacking one.
    // source interfaces: parent module's own + each instance's module's.
    let mut new_ifaces: Vec<(String, Interface)> = Vec::new(); // (module to extend, iface)
    let mut consider = |src_module: &Module, holder: &str| {
        for iface in &src_module.interfaces {
            if !iface.pipelinable() {
                continue;
            }
            // Map each interface port through the wires to one peer module.
            let mapped: Option<Vec<(String, PeerPort)>> = iface
                .ports()
                .iter()
                .map(|p| {
                    peers
                        .get(&(holder.to_string(), p.to_string()))
                        .map(|pp| (p.to_string(), pp.clone()))
                })
                .collect();
            let Some(mapped) = mapped else { continue };
            // All ports must land on the same peer holder.
            let first_holder = &mapped[0].1.peer_holder;
            if !mapped.iter().all(|(_, pp)| &pp.peer_holder == first_holder) {
                continue;
            }
            let peer_module_name = mapped[0].1.peer_module.clone();
            if peer_module_name == src_module.name {
                continue;
            }
            let Some(peer_module) = design.module(&peer_module_name) else {
                continue;
            };
            // Peer must not already cover any of these ports.
            if mapped
                .iter()
                .any(|(_, pp)| peer_module.interface_of(&pp.peer_port).is_some())
            {
                continue;
            }
            let port_map: BTreeMap<&str, &str> = mapped
                .iter()
                .map(|(src, pp)| (src.as_str(), pp.peer_port.as_str()))
                .collect();
            // Name the mirrored interface after its own ports (several
            // interfaces can be inferred onto one module; names must stay
            // unique so passes can address them).
            let mirrored = match iface {
                Interface::Handshake {
                    data, valid, ready, ..
                } => Interface::Handshake {
                    name: format!("{}_inferred", port_map[valid.as_str()]),
                    data: data.iter().map(|d| port_map[d.as_str()].to_string()).collect(),
                    valid: port_map[valid.as_str()].to_string(),
                    ready: port_map[ready.as_str()].to_string(),
                    clk: None,
                },
                Interface::Feedforward { ports, .. } => Interface::Feedforward {
                    name: format!("{}_inferred", port_map[ports[0].as_str()]),
                    ports: ports.iter().map(|p| port_map[p.as_str()].to_string()).collect(),
                },
                _ => continue,
            };
            new_ifaces.push((peer_module_name, mirrored));
        }
    };

    let g = design.module(gname).unwrap();
    consider(g, "");
    for inst in g.instances() {
        if let Some(m) = design.module(&inst.module_name) {
            consider(m, &inst.instance_name);
        }
    }

    let mut created = 0;
    for (mname, iface) in new_ifaces {
        // Interface lists don't feed the connectivity index (nets, ports
        // and instances are untouched), so this edit keeps the caches
        // valid without an invalidation.
        let m = design.module_mut(&mname).unwrap();
        // Double-check no overlap was created meanwhile.
        if iface.ports().iter().any(|p| m.interface_of(p).is_some()) {
            continue;
        }
        ctx.log(format!(
            "iface-infer: {} gains {} interface '{}'",
            mname,
            iface.kind(),
            iface.name()
        ));
        m.interfaces.push(iface);
        created += 1;
    }
    Ok(created)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::passes::rebuild;

    /// After rebuilding the LLM example, the aux module has bare ports;
    /// inference must mirror the handshake interfaces of its siblings.
    fn rebuilt_llm() -> Design {
        let mut d = Design::new("LLM");
        let input_loader = LeafBuilder::verilog_stub("InputLoader")
            .clk_rst()
            .handshake("o", Dir::Out, 64)
            .build();
        let layers = LeafBuilder::verilog_stub("Layers")
            .clk_rst()
            .handshake("i", Dir::In, 64)
            .build();
        d.add(input_loader);
        d.add(layers);
        let top_src = r#"
module LLM (input wire ap_clk, input wire ap_rst_n);
  wire [63:0] a; wire a_v; wire a_r;
  wire [63:0] b; wire b_v; wire b_r;
  reg hold;
  always @(posedge ap_clk) hold <= ~hold;
  InputLoader il (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
                  .o(a), .o_vld(a_v), .o_rdy(a_r));
  Layers ly (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
             .i(b), .i_vld(b_v), .i_rdy(b_r));
endmodule
"#;
        let mut top = Module::leaf("LLM", SourceFormat::Verilog, top_src);
        top.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("ap_rst_n", Dir::In, 1),
        ];
        top.interfaces = vec![
            Interface::Clock {
                port: "ap_clk".into(),
            },
            Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            },
        ];
        d.add(top);
        rebuild::rebuild(&mut d, "LLM", &mut PassContext::new()).unwrap();
        d
    }

    #[test]
    fn aux_inherits_sibling_handshakes() {
        let mut d = rebuilt_llm();
        InterfaceInference
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        let aux = d.module("LLM_aux").unwrap();
        let hs: Vec<_> = aux
            .interfaces
            .iter()
            .filter(|i| i.kind() == "handshake")
            .collect();
        // One mirrored from InputLoader.o, one from Layers.i.
        assert_eq!(hs.len(), 2, "{:?}", aux.interfaces);
        // The aux port wired to il.o is il_o; check coverage.
        assert!(aux.interface_of("il_o").is_some());
        assert!(aux.interface_of("ly_i").is_some());
    }

    #[test]
    fn inference_is_idempotent() {
        let mut d = rebuilt_llm();
        let mut ctx = PassContext::new();
        InterfaceInference.run(&mut d, &mut ctx).unwrap();
        let after_once = d.clone();
        InterfaceInference.run(&mut d, &mut ctx).unwrap();
        assert_eq!(d, after_once);
    }

    #[test]
    fn no_overwrite_of_existing_interfaces() {
        let mut d = rebuilt_llm();
        // Pre-install a feedforward covering il_o on the aux.
        let aux = d.module_mut("LLM_aux").unwrap();
        aux.interfaces.push(Interface::NonPipeline {
            name: "pre".into(),
            ports: vec!["il_o".into(), "il_o_vld".into(), "il_o_rdy".into()],
        });
        InterfaceInference
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        let aux = d.module("LLM_aux").unwrap();
        assert_eq!(aux.interface_of("il_o").unwrap().name(), "pre");
    }

    #[test]
    fn parent_interface_propagates_to_child() {
        // Grouped module with a handshake on its own ports, child lacking.
        let child = LeafBuilder::verilog_stub("C")
            .port("x", Dir::In, 8)
            .port("x_v", Dir::In, 1)
            .port("x_r", Dir::Out, 1)
            .build();
        let g = GroupedBuilder::new("G")
            .port("s", Dir::In, 8)
            .port("s_v", Dir::In, 1)
            .port("s_r", Dir::Out, 1)
            .iface(Interface::Handshake {
                name: "s".into(),
                data: vec!["s".into()],
                valid: "s_v".into(),
                ready: "s_r".into(),
                clk: None,
            })
            .inst("c0", "C", &[("x", "s"), ("x_v", "s_v"), ("x_r", "s_r")])
            .build();
        let mut d = Design::new("G");
        d.add(child);
        d.add(g);
        InterfaceInference
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        let c = d.module("C").unwrap();
        assert_eq!(c.interface_of("x").unwrap().kind(), "handshake");
    }
}

//! Global pass registry (§3.3): stable names → pass factories, plus
//! named pipelines, so every transformation in the repo is resolvable by
//! name and arbitrary compositions can be run from the CLI:
//!
//! ```text
//! rsir passes
//! rsir pipeline "rebuild,iface-infer,partition-aux,passthrough,iface-infer,flatten"
//! rsir pipeline analyze-structure --bench llama2
//! ```
//!
//! A registry entry is a plain `fn(Option<&str>) -> Result<Box<dyn Pass>>`
//! factory keyed by a stable name. Parameterless passes reject an
//! argument; parameterized ones (`rebuild-module=TARGET`, …) require one.
//! Named pipelines expand to pass sequences, so the integrated flow's
//! stages are themselves registry-resolvable (see [`ANALYZE_STRUCTURE`]).
//!
//! ```
//! use rsir::passes::registry;
//! let pipeline = registry::build("iface-infer,flatten").unwrap();
//! assert_eq!(pipeline.len(), 2);
//! assert!(registry::build("no-such-pass").is_err());
//! ```

use super::flatten::Flatten;
use super::group::Group;
use super::iface_infer::InterfaceInference;
use super::manager::{Pass, Pipeline};
use super::partition::{Partition, PartitionAllAux};
use super::passthrough::Passthrough;
use super::pipeline_insert::InsertRelayStation;
use super::rebuild::{HierarchyRebuild, RebuildAll};
use crate::plugins::platform::PlatformAnalyze;
use anyhow::{bail, Result};
use std::fmt;

/// Registry name of the stages-1–2 pipeline of the integrated flow
/// (communication analysis + partitioning), shared by
/// [`analyze_structure`](crate::coordinator::flow::analyze_structure),
/// [`run_baseline`](crate::coordinator::flow::run_baseline) and
/// [`run_hlps`](crate::coordinator::flow::run_hlps).
pub const ANALYZE_STRUCTURE: &str = "analyze-structure";

type Factory = fn(Option<&str>) -> Result<Box<dyn Pass>>;

/// One registered pass: a stable name, a one-line description, and a
/// factory producing a fresh boxed instance.
pub struct PassEntry {
    pub name: &'static str,
    pub description: &'static str,
    /// Argument placeholder when the pass is parameterized
    /// (`name=<arg>` in a spec), `None` for parameterless passes.
    pub arg: Option<&'static str>,
    factory: Factory,
}

impl PassEntry {
    /// Instantiate this pass with an optional `name=arg` argument.
    pub fn create(&self, arg: Option<&str>) -> Result<Box<dyn Pass>> {
        (self.factory)(arg)
    }
}

/// One registered named pipeline: a name resolving to a pass spec.
pub struct PipelineEntry {
    pub name: &'static str,
    pub description: &'static str,
    /// The pass composition, in [`parse_spec`] syntax.
    pub spec: &'static str,
}

fn no_arg(name: &str, arg: Option<&str>) -> Result<()> {
    match arg {
        None => Ok(()),
        Some(a) => bail!("pass '{name}' takes no argument (got '{a}')"),
    }
}

fn req_arg<'a>(name: &str, placeholder: &str, arg: Option<&'a str>) -> Result<&'a str> {
    arg.ok_or_else(|| anyhow::anyhow!("pass '{name}' requires an argument: {name}={placeholder}"))
}

/// All registered passes, sorted by name. Every `Pass` implementation in
/// the crate — including pass-ified plugin analyzers — appears here.
pub fn passes() -> &'static [PassEntry] {
    static ENTRIES: &[PassEntry] = &[
        PassEntry {
            name: "flatten",
            description: "Recursively inline grouped submodules into the top module",
            arg: None,
            factory: |a| {
                no_arg("flatten", a)?;
                Ok(Box::new(Flatten))
            },
        },
        PassEntry {
            name: "group",
            description: "Pull instances of a grouped module into a fresh grouped submodule",
            arg: Some("PARENT/NAME/INST1+INST2+..."),
            factory: |a| {
                let a = req_arg("group", "PARENT/NAME/INST1+INST2+...", a)?;
                let parts: Vec<&str> = a.split('/').collect();
                let (parent, name, members) = match parts[..] {
                    [p, n, m] => (p, n, m),
                    _ => bail!("group argument must be PARENT/NAME/INST1+INST2+... (got '{a}')"),
                };
                Ok(Box::new(Group {
                    parent: parent.to_string(),
                    group_name: name.to_string(),
                    members: members.split('+').map(str::to_string).collect(),
                }))
            },
        },
        PassEntry {
            name: "iface-infer",
            description: "Transfer interfaces onto modules lacking them from their siblings",
            arg: None,
            factory: |a| {
                no_arg("iface-infer", a)?;
                Ok(Box::new(InterfaceInference))
            },
        },
        PassEntry {
            name: "partition",
            description: "Split one aux instance into independently-floorplannable units",
            arg: Some("PARENT/AUX_INST"),
            factory: |a| {
                let a = req_arg("partition", "PARENT/AUX_INST", a)?;
                let Some((parent, aux)) = a.split_once('/') else {
                    bail!("partition argument must be PARENT/AUX_INST (got '{a}')");
                };
                Ok(Box::new(Partition {
                    parent: parent.to_string(),
                    aux_instance: aux.to_string(),
                }))
            },
        },
        PassEntry {
            name: "partition-aux",
            description: "Partition every aux instance (modules tagged aux_of) in the design",
            arg: None,
            factory: |a| {
                no_arg("partition-aux", a)?;
                Ok(Box::new(PartitionAllAux))
            },
        },
        PassEntry {
            name: "passthrough",
            description: "Bypass pure feed-through splits, merging their nets",
            arg: None,
            factory: |a| {
                no_arg("passthrough", a)?;
                Ok(Box::new(Passthrough))
            },
        },
        PassEntry {
            name: "platform-analyze",
            description: "Annotate leaf modules missing resource/timing metadata (vendor surrogate)",
            arg: None,
            factory: |a| {
                no_arg("platform-analyze", a)?;
                Ok(Box::new(PlatformAnalyze))
            },
        },
        PassEntry {
            name: "rebuild",
            description: "Rebuild all leaf Verilog modules with known children, to a fixpoint",
            arg: None,
            factory: |a| {
                no_arg("rebuild", a)?;
                Ok(Box::new(RebuildAll))
            },
        },
        PassEntry {
            name: "rebuild-module",
            description: "Rebuild one leaf Verilog module into a grouped module plus an aux",
            arg: Some("TARGET"),
            factory: |a| {
                let a = req_arg("rebuild-module", "TARGET", a)?;
                Ok(Box::new(HierarchyRebuild::new(a)))
            },
        },
        PassEntry {
            name: "relay-insert",
            description: "Insert a relay station on a handshake channel of the flat top",
            arg: Some("SRC_INST/IFACE[/STAGES]"),
            factory: |a| {
                let a = req_arg("relay-insert", "SRC_INST/IFACE[/STAGES]", a)?;
                let parts: Vec<&str> = a.split('/').collect();
                let (src, iface, stages) = match parts[..] {
                    [s, i] => (s, i, 1u32),
                    [s, i, n] => (s, i, n.parse()?),
                    _ => bail!("relay-insert argument must be SRC_INST/IFACE[/STAGES] (got '{a}')"),
                };
                Ok(Box::new(InsertRelayStation {
                    src_inst: src.to_string(),
                    iface: iface.to_string(),
                    stages,
                    slot: None,
                }))
            },
        },
    ];
    ENTRIES
}

/// All registered named pipelines.
pub fn pipelines() -> &'static [PipelineEntry] {
    static ENTRIES: &[PipelineEntry] = &[PipelineEntry {
        name: ANALYZE_STRUCTURE,
        description: "Stages 1-2 of the HLPS flow: communication analysis + partitioning \
                      (shared by the baseline and optimized flows)",
        spec: "platform-analyze,rebuild,iface-infer,partition-aux,passthrough,\
               iface-infer,platform-analyze,flatten",
    }];
    ENTRIES
}

fn find_pass(name: &str) -> Option<&'static PassEntry> {
    passes().iter().find(|e| e.name == name)
}

fn find_pipeline(name: &str) -> Option<&'static PipelineEntry> {
    pipelines().iter().find(|e| e.name == name)
}

/// One step of a parsed pipeline spec: a registry name plus its optional
/// `name=arg` argument. `Display` renders the spec syntax back, so
/// `render_spec(&parse_spec(s)?)` round-trips.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassInvocation {
    pub name: String,
    pub arg: Option<String>,
}

impl fmt::Display for PassInvocation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.arg {
            Some(a) => write!(f, "{}={a}", self.name),
            None => write!(f, "{}", self.name),
        }
    }
}

/// Parse a comma-separated pipeline spec (`"rebuild,iface-infer"`,
/// `"rebuild-module=LLM,flatten"`). Whitespace around items is ignored;
/// names are *not* resolved here (that happens in [`build`]).
pub fn parse_spec(spec: &str) -> Result<Vec<PassInvocation>> {
    let mut out = Vec::new();
    for raw in spec.split(',') {
        let item = raw.trim();
        if item.is_empty() {
            bail!("empty pass name in pipeline spec '{spec}'");
        }
        let (name, arg) = match item.split_once('=') {
            Some((n, a)) => (n.trim(), Some(a.trim().to_string())),
            None => (item, None),
        };
        if name.is_empty() {
            bail!("empty pass name in pipeline spec '{spec}'");
        }
        // `name=` would sail past the factories' argument checks and fail
        // late with a confusing downstream error; reject it at parse time.
        if matches!(&arg, Some(a) if a.is_empty()) {
            bail!("empty argument in pipeline spec item '{item}'");
        }
        out.push(PassInvocation {
            name: name.to_string(),
            arg,
        });
    }
    Ok(out)
}

/// Render invocations back to canonical spec syntax.
pub fn render_spec(invocations: &[PassInvocation]) -> String {
    invocations
        .iter()
        .map(|i| i.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

/// Build a runnable [`Pipeline`] from a spec. Items may name passes or
/// registered pipelines (which expand in place, recursively).
pub fn build(spec: &str) -> Result<Pipeline> {
    build_named("pipeline", spec)
}

/// Resolve a registered pipeline by name (e.g. [`ANALYZE_STRUCTURE`]).
pub fn named(name: &str) -> Result<Pipeline> {
    let Some(entry) = find_pipeline(name) else {
        bail!(
            "unknown pipeline '{name}'; registered: {}",
            pipelines()
                .iter()
                .map(|p| p.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    };
    build_named(entry.name, entry.spec)
}

fn build_named(name: &str, spec: &str) -> Result<Pipeline> {
    let mut pipeline = Pipeline::named(name);
    for inv in parse_spec(spec)? {
        pipeline = push(pipeline, &inv, 4)?;
    }
    Ok(pipeline)
}

fn push(pipeline: Pipeline, inv: &PassInvocation, depth: usize) -> Result<Pipeline> {
    if let Some(entry) = find_pipeline(&inv.name) {
        if inv.arg.is_some() {
            bail!("pipeline '{}' takes no argument", inv.name);
        }
        if depth == 0 {
            bail!("pipeline '{}' nests too deeply", inv.name);
        }
        let mut pipeline = pipeline;
        for sub in parse_spec(entry.spec)? {
            pipeline = push(pipeline, &sub, depth - 1)?;
        }
        return Ok(pipeline);
    }
    let Some(entry) = find_pass(&inv.name) else {
        bail!(
            "unknown pass '{}'; registered: {}",
            inv.name,
            passes()
                .iter()
                .map(|p| p.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    };
    Ok(pipeline.add_boxed(entry.create(inv.arg.as_deref())?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_descriptions_present() {
        let names: Vec<&str> = passes().iter().map(|e| e.name).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "registry must stay sorted by name");
        assert!(passes().iter().all(|e| !e.description.is_empty()));
    }

    #[test]
    fn factory_arg_validation() {
        assert!(find_pass("flatten").unwrap().create(None).is_ok());
        assert!(find_pass("flatten").unwrap().create(Some("x")).is_err());
        assert!(find_pass("rebuild-module").unwrap().create(None).is_err());
        let p = find_pass("rebuild-module").unwrap().create(Some("LLM")).unwrap();
        assert_eq!(p.name(), "rebuild-module");
    }

    /// The registry key IS the pass's `name()`, and the table's
    /// description matches the trait's `description()` — so `rsir
    /// pipeline` output (which prints `Pass::name()`) is always valid
    /// `rsir pipeline` input, and the two description sources can't
    /// drift.
    #[test]
    fn entries_agree_with_pass_impls() {
        for entry in passes() {
            // Parameterized passes need a plausible dummy argument.
            let arg = entry.arg.map(|_| match entry.name {
                "group" => "Top/G/a+b",
                "partition" => "Top/aux0",
                "rebuild-module" => "M",
                "relay-insert" => "src/o",
                other => panic!("no dummy arg for '{other}'"),
            });
            let pass = entry.create(arg).unwrap();
            assert_eq!(pass.name(), entry.name);
            assert_eq!(pass.description(), entry.description);
        }
    }

    #[test]
    fn named_pipeline_expands_in_spec() {
        let p = build("analyze-structure").unwrap();
        assert_eq!(p.len(), 8);
        // A pipeline name composes with plain passes.
        let p = build("analyze-structure,flatten").unwrap();
        assert_eq!(p.len(), 9);
    }
}

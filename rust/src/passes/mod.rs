//! Composable transformation passes (§3.3).
//!
//! Every transformation implements [`Pass`] and is registered by stable
//! name in [`registry`]; the flow's analysis stages run them through the
//! instrumented [`Pipeline`] rather than hand-calling `pass.run()`.
//! (The coordinator's floorplanning/pipelining stages 3–4 remain plain
//! functions — see `docs/ARCHITECTURE.md`.)

pub mod flatten;
pub mod group;
pub mod iface_infer;
pub mod manager;
pub mod partition;
pub mod passthrough;
pub mod pipeline_insert;
pub mod rebuild;
pub mod registry;

pub use manager::{
    Diagnostic, DrcOutcome, IndexPolicy, Pass, PassContext, PassManager, Pipeline, PipelineReport,
    Severity,
};

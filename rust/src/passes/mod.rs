//! Composable transformation passes (§3.3).

pub mod flatten;
pub mod group;
pub mod iface_infer;
pub mod manager;
pub mod partition;
pub mod passthrough;
pub mod pipeline_insert;
pub mod rebuild;

pub use manager::{Pass, PassContext, PassManager};

//! Hierarchy Rebuild Pass (§3.3, Fig 10b).
//!
//! Converts a *leaf* Verilog module into a *grouped* module containing its
//! extracted submodule instances plus an **aux module** holding all
//! residual logic (always blocks, assigns, unknown-IP instances). The
//! grouped module keeps the original name, ports and interfaces; every
//! extracted connection is rerouted through a fresh wire between the
//! submodule and a new flipped-direction aux port. Clock/reset
//! connections stay as direct broadcast nets (handled by invariant-exempt
//! clock distribution).

use crate::ir::core::*;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use crate::verilog::ast::{is_single_identifier, parse_literal};
use crate::verilog::parser::parse_module;
use crate::verilog::printer::print_module;
use crate::verilog::rewriter::extract_aux_with_skip;
use anyhow::{anyhow, bail, Context, Result};

/// Rebuild one leaf module (by name) into a grouped module + aux.
pub struct HierarchyRebuild {
    pub target: String,
}

impl HierarchyRebuild {
    pub fn new(target: impl Into<String>) -> Self {
        HierarchyRebuild {
            target: target.into(),
        }
    }
}

impl Pass for HierarchyRebuild {
    fn name(&self) -> &'static str {
        "rebuild-module"
    }

    fn description(&self) -> &'static str {
        "Rebuild one leaf Verilog module into a grouped module plus an aux"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        rebuild(design, &self.target, ctx)
            .with_context(|| format!("rebuilding module '{}'", self.target))
    }
}

/// Rebuild all leaf Verilog modules that instantiate known library
/// modules, top-down, until a fixpoint (the "restructure large modules"
/// step (b) of the integrated flow, §3.4).
pub struct RebuildAll;

impl Pass for RebuildAll {
    fn name(&self) -> &'static str {
        "rebuild"
    }

    fn description(&self) -> &'static str {
        "Rebuild all leaf Verilog modules with known children, to a fixpoint"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        loop {
            let candidate = design
                .modules
                .values()
                .find(|m| is_rebuild_candidate(design, m))
                .map(|m| m.name.clone());
            match candidate {
                Some(name) => rebuild(design, &name, ctx)
                    .with_context(|| format!("rebuilding module '{name}'"))?,
                None => return Ok(()),
            }
        }
    }
}

fn is_rebuild_candidate(design: &Design, m: &Module) -> bool {
    let Body::Leaf {
        format: SourceFormat::Verilog,
        source,
    } = &m.body
    else {
        return false;
    };
    // Cheap textual pre-filter, then parse.
    if !design
        .modules
        .keys()
        .any(|k| k != &m.name && source.contains(k.as_str()))
    {
        return false;
    }
    let Ok(vm) = parse_module(source) else {
        return false;
    };
    let has_known_child = vm.instances().any(|i| {
        design
            .modules
            .get(&i.module)
            .map(|t| t.name != m.name)
            .unwrap_or(false)
    });
    has_known_child
}

pub fn rebuild(design: &mut Design, target: &str, ctx: &mut PassContext) -> Result<()> {
    let module = design
        .module(target)
        .ok_or_else(|| anyhow!("module '{target}' not found"))?
        .clone();
    let Body::Leaf {
        format: SourceFormat::Verilog,
        source,
    } = &module.body
    else {
        bail!("'{target}' is not a Verilog leaf module");
    };
    let vm = parse_module(source)?;

    // Clock/reset identifiers on the parent: direct-connect those.
    let clockish: Vec<String> = module
        .interfaces
        .iter()
        .filter(|i| matches!(i, Interface::Clock { .. } | Interface::Reset { .. }))
        .flat_map(|i| i.ports())
        .map(|s| s.to_string())
        .collect();

    let lookup = |mname: &str, pname: &str| -> Option<(Dir, u32)> {
        let m = design.module(mname)?;
        if m.name == target {
            return None; // no self-recursion
        }
        m.port(pname).map(|p| (p.dir, p.width))
    };
    // Identifier use counts across the module: a parent-port identifier
    // used by exactly one connection (and no residual logic) can connect
    // the submodule directly — no phantom aux feed-through.
    let mut id_uses: std::collections::BTreeMap<String, usize> = Default::default();
    {
        use crate::verilog::ast::{expr_identifiers, VItem};
        let mut bump = |ids: Vec<String>| {
            for id in ids {
                *id_uses.entry(id).or_default() += 1;
            }
        };
        for item in &vm.items {
            match item {
                VItem::Assign(a) => {
                    bump(expr_identifiers(&a.lhs));
                    bump(expr_identifiers(&a.rhs));
                }
                VItem::Raw(r) => bump(expr_identifiers(r)),
                VItem::Instance(i) => {
                    for (_, e) in &i.conns {
                        bump(expr_identifiers(e));
                    }
                }
                VItem::Net(_) => {}
            }
        }
    }
    let parent_port_names: Vec<String> = module.ports.iter().map(|p| p.name.clone()).collect();
    let skip = |_inst: &crate::verilog::ast::VInst, port: &str, expr: &str| -> bool {
        let _ = port;
        let e = expr.trim();
        if !is_single_identifier(e) {
            return false;
        }
        if clockish.iter().any(|c| c == e) {
            return true;
        }
        // Single-use parent port: direct connection.
        parent_port_names.iter().any(|p| p == e)
            && id_uses.get(e).copied().unwrap_or(0) == 1
    };
    let aux_name = design.fresh_module_name(&format!("{target}_aux"));
    let mut split = extract_aux_with_skip(&vm, &aux_name, &lookup, &skip)?;
    if split.extracted.is_empty() {
        ctx.log(format!("rebuild {target}: no extractable instances"));
        return Ok(());
    }

    // Parent ports consumed by a direct (skipped, non-clock) connection
    // leave the aux entirely — otherwise the net would gain a third
    // endpoint.
    let direct_ports: std::collections::BTreeSet<String> = split
        .extracted
        .iter()
        .flat_map(|e| e.bindings.iter())
        .filter(|b| b.aux_port.is_empty())
        .map(|b| b.expr.trim().to_string())
        .filter(|e| {
            parent_port_names.iter().any(|p| p == e) && !clockish.iter().any(|c| c == e)
        })
        .collect();
    split.aux.ports.retain(|p| !direct_ports.contains(&p.name));

    // A split whose aux holds no residual items and whose remaining
    // ports are all clock/reset broadcasts carries no logic: every
    // extracted connection is direct. Skip the aux entirely — an empty
    // aux that survives downstream passes would lose its interface-less
    // clock/reset declarations on a later export/import round trip.
    let skip_aux = split.aux.items.is_empty()
        && split
            .extracted
            .iter()
            .all(|e| e.bindings.iter().all(|b| b.aux_port.is_empty()))
        && split
            .aux
            .ports
            .iter()
            .all(|p| clockish.iter().any(|c| c == &p.name));

    // Build the aux leaf module.
    let mut aux = Module::leaf(&aux_name, SourceFormat::Verilog, print_module(&split.aux));
    aux.ports = split
        .aux
        .ports
        .iter()
        .map(|p| Port::new(&p.name, p.dir, p.width))
        .collect();
    // Parent clock/reset interfaces also apply to the aux's copies.
    for iface in &module.interfaces {
        if matches!(iface, Interface::Clock { .. } | Interface::Reset { .. }) {
            aux.interfaces.push(iface.clone());
        }
    }
    aux.metadata
        .insert("aux_of", crate::util::json::Json::str(target));

    // Build the grouped module replacing the original leaf.
    let mut grouped = Module::grouped(target);
    grouped.ports = module.ports.clone();
    grouped.interfaces = module.interfaces.clone();
    grouped.metadata = module.metadata.clone();

    // Aux instance: parent ports connect straight through (same names),
    // except those consumed by direct submodule connections.
    let mut aux_inst = Instance::new(format!("{aux_name}_inst"), &aux_name);
    for p in &module.ports {
        if !direct_ports.contains(&p.name) {
            aux_inst.connect(&p.name, ConnExpr::id(&p.name));
        }
    }

    let mut used_wires: std::collections::BTreeSet<String> =
        grouped.ports.iter().map(|p| p.name.clone()).collect();

    for e in &split.extracted {
        let mut inst = Instance::new(&e.inst.name, &e.inst.module);
        for b in &e.bindings {
            if b.aux_port.is_empty() {
                let expr = b.expr.trim();
                if expr.is_empty() {
                    inst.connect(&b.sub_port, ConnExpr::Open);
                } else if clockish.iter().any(|c| c == expr)
                    || parent_port_names.iter().any(|p| p == expr)
                {
                    // Direct clock/reset broadcast or single-use parent port.
                    inst.connect(&b.sub_port, ConnExpr::id(expr));
                } else if let Some((w, v)) = parse_literal(expr) {
                    inst.connect(
                        &b.sub_port,
                        ConnExpr::Const {
                            width: w.min(b.width),
                            value: v,
                        },
                    );
                } else {
                    bail!(
                        "unexpected skipped binding {}.{} = '{}'",
                        e.inst.name,
                        b.sub_port,
                        expr
                    );
                }
                continue;
            }
            // Fresh wire joining submodule port and aux port.
            let mut wname = format!("w_{}", b.aux_port);
            while used_wires.contains(&wname) {
                wname.push('_');
            }
            used_wires.insert(wname.clone());
            grouped.wires_mut().push(Wire {
                name: wname.clone(),
                width: b.width,
            });
            inst.connect(&b.sub_port, ConnExpr::id(&wname));
            aux_inst.connect(&b.aux_port, ConnExpr::id(&wname));
        }
        grouped.instances_mut().push(inst);
    }
    if skip_aux {
        ctx.log(format!(
            "rebuild {target}: extracted {} instances into grouped module (no aux needed)",
            split.extracted.len()
        ));
    } else {
        grouped.instances_mut().push(aux_inst);
        ctx.namemap.record("hierarchy-rebuild", target, &aux_name);
        ctx.log(format!(
            "rebuild {target}: extracted {} instances into grouped module + {aux_name}",
            split.extracted.len()
        ));
        ctx.index.touch(&aux_name);
        design.add(aux);
    }

    // The add announces itself to the connectivity index: the grouped
    // module replaces the leaf under the same name.
    ctx.index.touch(target);
    design.add(grouped); // replaces the leaf under the same name
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::LeafBuilder;
    use crate::ir::validate;

    /// The motivating LLM example of Fig 4a: Verilog top with InputLoader
    /// (RTL), FIFO (RTL), Layers (HLS) + control logic in the body.
    fn llm_design() -> Design {
        let mut d = Design::new("LLM");
        let input_loader = LeafBuilder::verilog_stub("InputLoader")
            .clk_rst()
            .handshake("o", Dir::Out, 64)
            .build();
        let fifo = LeafBuilder::verilog_stub("FIFO")
            .clk_rst()
            .handshake("I", Dir::In, 64)
            .handshake("O", Dir::Out, 64)
            .build();
        let layers = LeafBuilder::verilog_stub("Layers")
            .clk_rst()
            .handshake("i", Dir::In, 64)
            .handshake("o", Dir::Out, 32)
            .build();
        d.add(input_loader);
        d.add(fifo);
        d.add(layers);

        let top_src = r#"
module LLM (
  input  wire ap_clk,
  input  wire ap_rst_n,
  output wire [31:0] out_data,
  output wire out_vld,
  input  wire out_rdy
);
  wire [63:0] a; wire a_v; wire a_r;
  wire [63:0] b; wire b_v; wire b_r;
  reg [3:0] ctr;
  always @(posedge ap_clk) ctr <= ctr + 1;

  InputLoader il (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
                  .o(a), .o_vld(a_v), .o_rdy(a_r));
  FIFO fifo (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
             .I(a), .I_vld(a_v), .I_rdy(a_r),
             .O(b), .O_vld(b_v), .O_rdy(b_r));
  Layers layers (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
                 .i(b), .i_vld(b_v & ~ctr[3]), .i_rdy(b_r),
                 .o(out_data), .o_vld(out_vld), .o_rdy(out_rdy));
endmodule
"#;
        let mut top = Module::leaf("LLM", SourceFormat::Verilog, top_src);
        top.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("ap_rst_n", Dir::In, 1),
            Port::new("out_data", Dir::Out, 32),
            Port::new("out_vld", Dir::Out, 1),
            Port::new("out_rdy", Dir::In, 1),
        ];
        top.interfaces = vec![
            Interface::Clock {
                port: "ap_clk".into(),
            },
            Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            },
            Interface::Handshake {
                name: "out".into(),
                data: vec!["out_data".into()],
                valid: "out_vld".into(),
                ready: "out_rdy".into(),
                clk: Some("ap_clk".into()),
            },
        ];
        d.add(top);
        d
    }

    #[test]
    fn rebuild_produces_grouped_plus_aux() {
        let mut d = llm_design();
        let mut ctx = PassContext::new();
        rebuild(&mut d, "LLM", &mut ctx).unwrap();
        let top = d.module("LLM").unwrap();
        assert!(top.is_grouped());
        // 3 extracted + 1 aux instance.
        assert_eq!(top.instances().len(), 4);
        assert!(d.module("LLM_aux").unwrap().is_leaf());
        validate::assert_clean(&d);
    }

    #[test]
    fn clock_connects_directly_not_via_aux() {
        let mut d = llm_design();
        rebuild(&mut d, "LLM", &mut PassContext::new()).unwrap();
        let top = d.module("LLM").unwrap();
        let il = top.instance("il").unwrap();
        assert_eq!(il.connection("ap_clk"), Some(&ConnExpr::id("ap_clk")));
        // Aux has no il_ap_clk port.
        assert!(d.module("LLM_aux").unwrap().port("il_ap_clk").is_none());
    }

    #[test]
    fn complex_expression_lands_in_aux() {
        let mut d = llm_design();
        rebuild(&mut d, "LLM", &mut PassContext::new()).unwrap();
        let aux = d.module("LLM_aux").unwrap();
        let Body::Leaf { source, .. } = &aux.body else {
            panic!()
        };
        // `.i_vld(b_v & ~ctr[3])` became an aux assign.
        assert!(source.contains("assign layers_i_vld = b_v & ~ctr[3];"), "{source}");
        // Residual always block survives.
        assert!(source.contains("ctr <= ctr + 1"));
    }

    #[test]
    fn grouped_ports_unchanged() {
        let mut d = llm_design();
        let before = d.module("LLM").unwrap().ports.clone();
        rebuild(&mut d, "LLM", &mut PassContext::new()).unwrap();
        assert_eq!(d.module("LLM").unwrap().ports, before);
        assert_eq!(d.module("LLM").unwrap().interfaces.len(), 3);
    }

    #[test]
    fn namemap_records_aux() {
        let mut d = llm_design();
        let mut ctx = PassContext::new();
        rebuild(&mut d, "LLM", &mut ctx).unwrap();
        assert_eq!(ctx.namemap.trace("LLM_aux"), "LLM");
    }

    #[test]
    fn rebuild_all_reaches_fixpoint() {
        let mut d = llm_design();
        let mut ctx = PassContext::new();
        RebuildAll.run(&mut d, &mut ctx).unwrap();
        assert!(d.module("LLM").unwrap().is_grouped());
        // Running again is a no-op.
        let before = d.clone();
        RebuildAll.run(&mut d, &mut ctx).unwrap();
        assert_eq!(d, before);
    }

    #[test]
    fn all_direct_connections_skip_the_aux() {
        // A parent whose child connections are all clock broadcasts or
        // single-use parent ports needs no aux at all.
        let mut d = Design::new("Wrap");
        let child = LeafBuilder::verilog_stub("Child")
            .clk_rst()
            .handshake("i", Dir::In, 8)
            .build();
        d.add(child);
        let src = r#"
module Wrap (
  input wire ap_clk,
  input wire ap_rst_n,
  input wire [7:0] x_i,
  input wire x_i_vld,
  output wire x_i_rdy
);
  Child c0 (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
            .i(x_i), .i_vld(x_i_vld), .i_rdy(x_i_rdy));
endmodule
"#;
        let mut top = Module::leaf("Wrap", SourceFormat::Verilog, src);
        top.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("ap_rst_n", Dir::In, 1),
            Port::new("x_i", Dir::In, 8),
            Port::new("x_i_vld", Dir::In, 1),
            Port::new("x_i_rdy", Dir::Out, 1),
        ];
        top.interfaces = vec![
            Interface::Clock {
                port: "ap_clk".into(),
            },
            Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            },
            Interface::Handshake {
                name: "x_i".into(),
                data: vec!["x_i".into()],
                valid: "x_i_vld".into(),
                ready: "x_i_rdy".into(),
                clk: Some("ap_clk".into()),
            },
        ];
        d.add(top);
        rebuild(&mut d, "Wrap", &mut PassContext::new()).unwrap();
        let top = d.module("Wrap").unwrap();
        assert!(top.is_grouped());
        assert_eq!(top.instances().len(), 1, "no aux instance expected");
        assert!(d.module("Wrap_aux").is_none(), "no aux module expected");
        assert_eq!(
            top.instance("c0").unwrap().connection("i"),
            Some(&ConnExpr::id("x_i"))
        );
        validate::assert_clean(&d);
    }

    #[test]
    fn rebuild_via_pass_manager_with_drc() {
        let mut d = llm_design();
        let mut ctx = PassContext::new();
        crate::passes::manager::PassManager::new()
            .add(HierarchyRebuild::new("LLM"))
            .run(&mut d, &mut ctx)
            .unwrap();
    }
}

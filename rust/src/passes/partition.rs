//! Partitioning Pass (§3.3, Fig 10d).
//!
//! Splits an aux leaf module into independently-floorplannable units: the
//! module is converted to a netlist view (our structural Verilog parse),
//! port connectivity is analyzed with union-find — identifiers co-occurring
//! in a statement are conservatively connected — and each disjoint
//! component becomes a **split**: a thin wrapper around the original aux
//! exposing only that component's ports ("the splits are created by
//! wrapping the original aux module … Unconnected logic remains undriven,
//! which will be eliminated by subsequent EDA flows"). Clock and reset are
//! excluded from the analysis and re-distributed to every split.
//!
//! Components whose logic is nothing but port-to-port assigns are tagged
//! `passthrough_pairs` for the passthrough pass to bypass.

use crate::ir::core::*;
use crate::ir::intern::Interner;
use crate::passes::manager::{IndexPolicy, Pass, PassContext};
use crate::util::json::{Json, JsonObj};
use crate::util::union_find::UnionFind;
use crate::verilog::ast::{expr_identifiers, is_single_identifier, VItem};
use crate::verilog::parser::parse_module;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Partition one aux instance inside a grouped parent.
pub struct Partition {
    pub parent: String,
    pub aux_instance: String,
}

impl Pass for Partition {
    fn name(&self) -> &'static str {
        "partition"
    }

    fn description(&self) -> &'static str {
        "Split one aux instance into independently-floorplannable units"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        partition_aux(design, &self.parent, &self.aux_instance, ctx)?;
        Ok(())
    }
}

/// Partition every aux instance (modules with `aux_of` metadata) found in
/// grouped modules — step (d) of the integrated flow.
pub struct PartitionAllAux;

impl Pass for PartitionAllAux {
    fn name(&self) -> &'static str {
        "partition-aux"
    }

    fn description(&self) -> &'static str {
        "Partition every aux instance (modules tagged aux_of) in the design"
    }

    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Tracked
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        // The cached inverse instance→parent map hands us every site that
        // instantiates an aux module, instead of rescanning each grouped
        // module's instance list.
        let aux_names: Vec<String> = design
            .modules
            .values()
            .filter(|m| m.metadata.contains_key("aux_of"))
            .map(|m| m.name.clone())
            .collect();
        let mut work: Vec<(String, usize, String)> = Vec::new();
        {
            let (sites, interner) = ctx.index.parents(design);
            for name in &aux_names {
                let Some(sym) = interner.get(name) else {
                    continue;
                };
                for site in sites.get(&sym).map(|v| v.as_slice()).unwrap_or(&[]) {
                    work.push((
                        interner.resolve(site.parent).to_string(),
                        site.decl,
                        interner.resolve(site.instance).to_string(),
                    ));
                }
            }
        }
        // (parent module name, declaration index) order — exactly the
        // order the historical nested scan visited the sites in.
        work.sort();
        for (parent, _, inst) in work {
            partition_aux(design, &parent, &inst, ctx)?;
        }
        Ok(())
    }
}

/// Returns the number of splits created (1 = nothing to split).
pub fn partition_aux(
    design: &mut Design,
    parent_name: &str,
    aux_inst_name: &str,
    ctx: &mut PassContext,
) -> Result<usize> {
    let parent = design
        .module(parent_name)
        .ok_or_else(|| anyhow!("missing parent '{parent_name}'"))?;
    let aux_inst = parent
        .instance(aux_inst_name)
        .ok_or_else(|| anyhow!("no instance '{aux_inst_name}' in '{parent_name}'"))?
        .clone();
    let aux = design
        .module(&aux_inst.module_name)
        .ok_or_else(|| anyhow!("missing module '{}'", aux_inst.module_name))?
        .clone();
    let Body::Leaf {
        format: SourceFormat::Verilog,
        source,
    } = &aux.body
    else {
        bail!("aux '{}' is not a Verilog leaf", aux.name);
    };
    let vm = parse_module(source)?;

    // Clock/reset ports excluded from connectivity.
    let clockish: BTreeSet<String> = aux
        .interfaces
        .iter()
        .filter(|i| matches!(i, Interface::Clock { .. } | Interface::Reset { .. }))
        .flat_map(|i| i.ports())
        .map(|s| s.to_string())
        .collect();

    // Identifier universe: everything appearing in the module, interned
    // to dense u32 symbols — the union-find runs over symbol indices.
    let mut interner = Interner::new();
    for p in &aux.ports {
        interner.intern(&p.name);
    }
    // Gather statement groups (each joins its identifiers).
    let mut groups: Vec<Vec<usize>> = Vec::new();
    // Pure alias assigns `lhs = rhs` (single identifiers both sides) for
    // feed-through chain detection, and whether any non-alias statement
    // touched each identifier.
    let mut alias_assigns: Vec<(String, String)> = Vec::new();
    let mut logic_stmt_roots: Vec<Vec<String>> = Vec::new();
    for item in &vm.items {
        let mut is_alias = false;
        let idents: Vec<String> = match item {
            VItem::Assign(a) => {
                let lhs = a.lhs.trim();
                let rhs = a.rhs.trim();
                if is_single_identifier(lhs) && is_single_identifier(rhs) {
                    alias_assigns.push((lhs.to_string(), rhs.to_string()));
                    is_alias = true;
                }
                let mut v = expr_identifiers(&a.lhs);
                v.extend(expr_identifiers(&a.rhs));
                v
            }
            VItem::Raw(r) => expr_identifiers(r),
            VItem::Instance(i) => {
                let mut v = Vec::new();
                for (_, e) in &i.conns {
                    v.extend(expr_identifiers(e));
                }
                v
            }
            VItem::Net(_) => continue,
        };
        let filtered: Vec<String> = idents
            .into_iter()
            .filter(|id| !clockish.contains(id))
            .collect();
        if !is_alias && !filtered.is_empty() {
            logic_stmt_roots.push(filtered.clone());
        }
        let idxs: Vec<usize> = filtered
            .iter()
            .map(|id| interner.intern(id).as_usize())
            .collect();
        if idxs.len() > 1 {
            groups.push(idxs);
        }
    }
    // Interface port merging: ports in a common interface go together.
    for iface in &aux.interfaces {
        if matches!(iface, Interface::Clock { .. } | Interface::Reset { .. }) {
            continue;
        }
        let idxs: Vec<usize> = iface
            .ports()
            .iter()
            .map(|p| interner.intern(p).as_usize())
            .collect();
        if idxs.len() > 1 {
            groups.push(idxs);
        }
    }

    let mut uf = UnionFind::new(interner.len());
    for g in &groups {
        for w in g.windows(2) {
            uf.union(w[0], w[1]);
        }
    }

    // Components restricted to (non-clock) ports.
    let mut comp_ports: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for p in &aux.ports {
        if clockish.contains(&p.name) {
            continue;
        }
        let root = uf.find(interner.get(&p.name).unwrap().as_usize());
        comp_ports.entry(root).or_default().push(p.name.clone());
    }
    // Identify pure-passthrough components: no non-alias logic touches the
    // component, and every output port resolves through the alias chain to
    // an input port.
    let mut logic_roots: BTreeSet<usize> = BTreeSet::new();
    for stmt in &logic_stmt_roots {
        for id in stmt {
            logic_roots.insert(uf.find(interner.get(id).unwrap().as_usize()));
        }
    }
    // Alias graph: lhs <- rhs.
    let driver_of: BTreeMap<&str, &str> = alias_assigns
        .iter()
        .map(|(l, r)| (l.as_str(), r.as_str()))
        .collect();
    let trace_to_input = |start: &str| -> Option<String> {
        let mut cur = start;
        for _ in 0..1000 {
            if let Some(p) = aux.port(cur) {
                if p.dir == Dir::In && cur != start {
                    return Some(cur.to_string());
                }
            }
            cur = driver_of.get(cur)?;
        }
        None
    };
    let mut pass_pairs_by_root: BTreeMap<usize, Vec<(String, String)>> = BTreeMap::new();
    for (&root, ports) in comp_ports.iter() {
        if logic_roots.contains(&root) {
            continue;
        }
        let outs: Vec<&String> = ports
            .iter()
            .filter(|p| aux.port(p).map(|q| q.dir == Dir::Out).unwrap_or(false))
            .collect();
        if outs.is_empty() {
            continue;
        }
        let pairs: Option<Vec<(String, String)>> = outs
            .iter()
            .map(|o| trace_to_input(o).map(|i| ((*o).clone(), i)))
            .collect();
        if let Some(pairs) = pairs {
            pass_pairs_by_root.insert(root, pairs);
        }
    }

    if comp_ports.len() <= 1 {
        // A lone component still matters when it is a pure feed-through:
        // splitting it off would just rename the aux, but leaving it
        // untagged would let a wire-only module survive the passthrough
        // pass (imported single-channel hierarchies rebuild into exactly
        // this shape). Tag the aux itself so passthrough can bypass it.
        if let Some((root, ports)) = comp_ports.iter().next() {
            if let Some(pairs) = pass_pairs_by_root.get(root) {
                let covered: BTreeSet<&str> = pairs
                    .iter()
                    .flat_map(|(a, b)| [a.as_str(), b.as_str()])
                    .collect();
                if ports.iter().all(|p| covered.contains(p.as_str())) {
                    let arr = pairs_json(pairs);
                    ctx.index
                        .edit(design, &aux.name)
                        .ok_or_else(|| anyhow!("missing module '{}'", aux.name))?
                        .metadata
                        .insert("passthrough_pairs", arr);
                    ctx.log(format!(
                        "partition {}: single pure component, tagged for passthrough",
                        aux.name
                    ));
                    return Ok(1);
                }
            }
        }
        ctx.log(format!("partition {}: single component, no split", aux.name));
        return Ok(1);
    }

    let total_bits: f64 = aux
        .ports
        .iter()
        .filter(|p| !clockish.contains(&p.name))
        .map(|p| p.width as f64)
        .sum();
    let aux_res = crate::ir::builder::module_resources(&aux).unwrap_or_else(|| {
        crate::eda::synth::estimate_verilog(source).unwrap_or(Resources::ZERO)
    });

    // Build split modules + instances.
    let clk_ports: Vec<Port> = aux
        .ports
        .iter()
        .filter(|p| clockish.contains(&p.name))
        .cloned()
        .collect();
    let mut new_instances: Vec<Instance> = Vec::new();
    let mut split_names: Vec<String> = Vec::new();
    for (k, (root, ports)) in comp_ports.iter().enumerate() {
        let split_name = design.fresh_module_name(&format!("{}_split{k}", aux.name));
        let mut sm = Module::leaf(
            &split_name,
            SourceFormat::Verilog,
            wrapper_verilog(&split_name, &aux, ports, &clk_ports),
        );
        for p in ports {
            sm.ports.push(aux.port(p).unwrap().clone());
        }
        for p in &clk_ports {
            sm.ports.push(p.clone());
        }
        // Interfaces covering this component's ports transfer over.
        for iface in &aux.interfaces {
            let ip = iface.ports();
            if ip.iter().all(|p| {
                ports.iter().any(|q| q == p) || clockish.contains(*p)
            }) {
                sm.interfaces.push(iface.clone());
            }
        }
        // Resource share by port-bit fraction.
        let bits: f64 = ports
            .iter()
            .map(|p| aux.port(p).unwrap().width as f64)
            .sum();
        let share = if total_bits > 0.0 { bits / total_bits } else { 0.0 };
        crate::ir::builder::set_module_resources(&mut sm, aux_res.scale(share));
        sm.metadata.insert("split_of", Json::str(&aux.name));
        if let Some(pairs) = pass_pairs_by_root.get(root) {
            let covered: BTreeSet<&str> = pairs
                .iter()
                .flat_map(|(a, b)| [a.as_str(), b.as_str()])
                .collect();
            if ports.iter().all(|p| covered.contains(p.as_str())) {
                sm.metadata.insert("passthrough_pairs", pairs_json(pairs));
            }
        }

        // Parent-side instance.
        let mut si = Instance::new(format!("{aux_inst_name}_s{k}"), &split_name);
        for p in ports {
            if let Some(v) = aux_inst.connection(p) {
                si.connections.push(Connection {
                    port: p.clone(),
                    value: v.clone(),
                });
            }
        }
        for p in &clk_ports {
            if let Some(v) = aux_inst.connection(&p.name) {
                si.connections.push(Connection {
                    port: p.name.clone(),
                    value: v.clone(),
                });
            }
        }
        ctx.namemap.record("partition", &aux.name, &split_name);
        ctx.index.touch(&split_name);
        split_names.push(split_name);
        new_instances.push(si);
        design.add(sm);
    }

    // Swap the aux instance for the splits (through the index, so only
    // the parent's connectivity cache is dirtied).
    let parent = ctx.index.edit(design, parent_name).unwrap();
    parent
        .instances_mut()
        .retain(|i| i.instance_name != aux_inst_name);
    let n = new_instances.len();
    parent.instances_mut().extend(new_instances);
    ctx.log(format!(
        "partition {}: {} splits [{}]",
        aux.name,
        n,
        split_names.join(", ")
    ));
    Ok(n)
}

/// `passthrough_pairs` metadata: `[{"out": o, "in": i}, ...]`.
fn pairs_json(pairs: &[(String, String)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(a, b)| {
                let mut o = JsonObj::new();
                o.insert("out", Json::str(a));
                o.insert("in", Json::str(b));
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Wrapper Verilog: instantiate the original aux, connect only this
/// split's ports (+ clock/reset); everything else left open.
fn wrapper_verilog(name: &str, aux: &Module, ports: &[String], clk_ports: &[Port]) -> String {
    let mut s = format!("// Split wrapper over {}: undriven logic is pruned by synthesis.\nmodule {name} (\n", aux.name);
    let all: Vec<&Port> = ports
        .iter()
        .map(|p| aux.port(p).unwrap())
        .chain(clk_ports.iter())
        .collect();
    for (i, p) in all.iter().enumerate() {
        let dir = match p.dir {
            Dir::In => "input  wire",
            Dir::Out => "output wire",
            Dir::InOut => "inout  wire",
        };
        let range = if p.width > 1 {
            format!("[{}:0] ", p.width - 1)
        } else {
            String::new()
        };
        let comma = if i + 1 < all.len() { "," } else { "" };
        s.push_str(&format!("  {dir} {range}{}{comma}\n", p.name));
    }
    s.push_str(");\n");
    s.push_str(&format!("  {} core (\n", aux.name));
    let conns: Vec<String> = aux
        .ports
        .iter()
        .map(|p| {
            if ports.iter().any(|q| q == &p.name) || clk_ports.iter().any(|c| c.name == p.name) {
                format!("    .{}({})", p.name, p.name)
            } else {
                format!("    .{}()", p.name)
            }
        })
        .collect();
    s.push_str(&conns.join(",\n"));
    s.push_str("\n  );\nendmodule\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::*;
    use crate::ir::validate;
    use crate::passes::iface_infer::InterfaceInference;
    use crate::passes::rebuild;

    /// LLM-style top whose body has TWO independent control blobs: one
    /// gating the loader→layer path, one a standalone RAM passthrough.
    fn design_with_aux() -> Design {
        let mut d = Design::new("LLM");
        d.add(
            LeafBuilder::verilog_stub("InputLoader")
                .clk_rst()
                .handshake("o", Dir::Out, 64)
                .build(),
        );
        d.add(
            LeafBuilder::verilog_stub("Layers")
                .clk_rst()
                .handshake("i", Dir::In, 64)
                .handshake("o", Dir::Out, 32)
                .build(),
        );
        d.add(
            LeafBuilder::verilog_stub("Buffer")
                .clk_rst()
                .handshake("i", Dir::In, 32)
                .build(),
        );
        let top_src = r#"
module LLM (input wire ap_clk, input wire ap_rst_n);
  wire [63:0] a; wire a_v; wire a_r;
  wire [31:0] q; wire q_v; wire q_r;
  wire [31:0] qq; wire qq_v; wire qq_r;
  reg gate;
  always @(posedge ap_clk) gate <= ~gate;

  // Component 1: loader -> layers with gated valid.
  InputLoader il (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
                  .o(a), .o_vld(a_v), .o_rdy(a_r));
  Layers ly (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
             .i(a), .i_vld(a_v & gate), .i_rdy(a_r),
             .o(q), .o_vld(q_v), .o_rdy(q_r));

  // Component 2: pure feed-through to the buffer (auxRAM-like).
  Buffer bf (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
             .i(qq), .i_vld(qq_v), .i_rdy(qq_r));
  assign qq = q;
  assign qq_v = q_v;
  assign q_r = qq_r;
endmodule
"#;
        let mut top = Module::leaf("LLM", SourceFormat::Verilog, top_src);
        top.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("ap_rst_n", Dir::In, 1),
        ];
        top.interfaces = vec![
            Interface::Clock {
                port: "ap_clk".into(),
            },
            Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            },
        ];
        d.add(top);
        d
    }

    fn prepared() -> (Design, PassContext) {
        let mut d = design_with_aux();
        let mut ctx = PassContext::new();
        rebuild::rebuild(&mut d, "LLM", &mut ctx).unwrap();
        InterfaceInference.run(&mut d, &mut ctx).unwrap();
        (d, ctx)
    }

    #[test]
    fn aux_splits_into_components() {
        let (mut d, mut ctx) = prepared();
        let n = partition_aux(&mut d, "LLM", "LLM_aux_inst", &mut ctx).unwrap();
        assert!(n >= 2, "expected ≥2 splits, got {n}");
        validate::assert_clean(&d);
        let top = d.module("LLM").unwrap();
        assert!(top.instance("LLM_aux_inst").is_none());
        assert!(top.instance("LLM_aux_inst_s0").is_some());
    }

    #[test]
    fn gate_logic_and_feedthrough_in_different_splits() {
        let (mut d, mut ctx) = prepared();
        partition_aux(&mut d, "LLM", "LLM_aux_inst", &mut ctx).unwrap();
        // Find the split carrying the ly_i_vld (gated) port and the one
        // carrying bf_i (feed-through).
        let split_of = |port: &str| -> Option<String> {
            d.modules
                .values()
                .find(|m| {
                    m.metadata.contains_key("split_of") && m.port(port).is_some()
                })
                .map(|m| m.name.clone())
        };
        let gated = split_of("ly_i_vld").expect("gated split");
        let ft = split_of("bf_i").expect("feedthrough split");
        assert_ne!(gated, ft);
        // The feed-through split is tagged for the passthrough pass.
        let ftm = d.module(&ft).unwrap();
        assert!(ftm.metadata.contains_key("passthrough_pairs"), "{ftm:?}");
        let gm = d.module(&gated).unwrap();
        assert!(!gm.metadata.contains_key("passthrough_pairs"));
    }

    #[test]
    fn splits_share_aux_resources() {
        let (mut d, mut ctx) = prepared();
        // Attach a known resource estimate to the aux first.
        crate::ir::builder::set_module_resources(
            d.module_mut("LLM_aux").unwrap(),
            Resources::new(1000.0, 500.0, 0.0, 0.0, 0.0),
        );
        partition_aux(&mut d, "LLM", "LLM_aux_inst", &mut ctx).unwrap();
        let total: f64 = d
            .modules
            .values()
            .filter(|m| m.metadata.contains_key("split_of"))
            .map(|m| crate::ir::builder::module_resources(m).unwrap().lut)
            .sum();
        assert!((total - 1000.0).abs() < 1.0, "split LUTs sum to {total}");
    }

    #[test]
    fn wrapper_verilog_parses_and_instantiates_core() {
        let (mut d, mut ctx) = prepared();
        partition_aux(&mut d, "LLM", "LLM_aux_inst", &mut ctx).unwrap();
        for m in d.modules.values().filter(|m| m.metadata.contains_key("split_of")) {
            let Body::Leaf { source, .. } = &m.body else {
                panic!()
            };
            let vm = crate::verilog::parser::parse_module(source).unwrap();
            assert_eq!(vm.instances().count(), 1);
            assert_eq!(vm.instances().next().unwrap().module, "LLM_aux");
        }
    }

    #[test]
    fn clock_distributed_to_every_split() {
        let (mut d, mut ctx) = prepared();
        partition_aux(&mut d, "LLM", "LLM_aux_inst", &mut ctx).unwrap();
        let top = d.module("LLM").unwrap();
        for inst in top.instances().iter().filter(|i| i.instance_name.starts_with("LLM_aux_inst_s")) {
            assert_eq!(inst.connection("ap_clk"), Some(&ConnExpr::id("ap_clk")));
        }
    }

    #[test]
    fn single_component_no_split() {
        // An aux whose ports are all interconnected stays whole.
        let mut d = Design::new("T");
        let mut aux = Module::leaf(
            "T_aux",
            SourceFormat::Verilog,
            "module T_aux(input [7:0] a, output [7:0] b);\nassign b = a + 1;\nendmodule",
        );
        aux.ports = vec![Port::new("a", Dir::In, 8), Port::new("b", Dir::Out, 8)];
        aux.metadata.insert("aux_of", Json::str("T"));
        d.add(aux);
        let top = GroupedBuilder::new("T")
            .port("x", Dir::In, 8)
            .port("y", Dir::Out, 8)
            .inst("aux0", "T_aux", &[("a", "x"), ("b", "y")])
            .build();
        d.add(top);
        let n = partition_aux(&mut d, "T", "aux0", &mut PassContext::new()).unwrap();
        assert_eq!(n, 1);
        assert!(d.module("T").unwrap().instance("aux0").is_some());
    }

    #[test]
    fn single_pure_component_tagged_on_aux() {
        // A wire-only aux (the shape a single-channel imported hierarchy
        // rebuilds into) keeps its lone component, but the aux itself is
        // tagged so the passthrough pass can bypass it.
        let mut d = Design::new("T");
        let mut aux = Module::leaf(
            "T_aux",
            SourceFormat::Verilog,
            "module T_aux(input [7:0] a, output [7:0] b);\nassign b = a;\nendmodule",
        );
        aux.ports = vec![Port::new("a", Dir::In, 8), Port::new("b", Dir::Out, 8)];
        aux.metadata.insert("aux_of", Json::str("T"));
        d.add(aux);
        let top = GroupedBuilder::new("T")
            .port("x", Dir::In, 8)
            .port("y", Dir::Out, 8)
            .inst("aux0", "T_aux", &[("a", "x"), ("b", "y")])
            .build();
        d.add(top);
        let n = partition_aux(&mut d, "T", "aux0", &mut PassContext::new()).unwrap();
        assert_eq!(n, 1);
        let aux = d.module("T_aux").unwrap();
        assert!(aux.metadata.contains_key("passthrough_pairs"), "{aux:?}");
        // The logic-bearing single component above stays untagged; this
        // one is picked up by the passthrough pass end to end.
        crate::passes::passthrough::Passthrough
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        assert!(d.module("T_aux").is_none(), "aux should be bypassed + gc'd");
    }
}

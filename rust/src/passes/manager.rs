//! Pass framework: each transformation "does one thing and does it well"
//! (§3.3); the manager sequences passes, keeps the original↔transformed
//! name mapping, and optionally runs DRC after every pass.

use crate::ir::core::Design;
use crate::ir::namemap::NameMap;
use crate::ir::validate;
use anyhow::{bail, Result};

/// Shared state threaded through a pass pipeline.
#[derive(Debug, Default)]
pub struct PassContext {
    pub namemap: NameMap,
    /// Run DRC after each pass and fail on violations.
    pub drc_after_each: bool,
    /// Human-readable log lines from passes.
    pub log: Vec<String>,
}

impl PassContext {
    pub fn new() -> PassContext {
        PassContext {
            drc_after_each: true,
            ..Default::default()
        }
    }

    pub fn log(&mut self, msg: impl Into<String>) {
        self.log.push(msg.into());
    }
}

/// A composable IR transformation.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()>;
}

/// Run a sequence of passes with DRC hooks.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl Default for PassManager {
    fn default() -> Self {
        Self::new()
    }
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager { passes: Vec::new() }
    }

    pub fn add(mut self, pass: impl Pass + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    pub fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()> {
        for pass in &self.passes {
            pass.run(design, ctx)?;
            ctx.log(format!("pass '{}' complete", pass.name()));
            if ctx.drc_after_each {
                let violations = validate::check(design);
                if !violations.is_empty() {
                    let mut msg =
                        format!("DRC failed after pass '{}':\n", pass.name());
                    for v in violations.iter().take(10) {
                        msg.push_str(&format!("  {v}\n"));
                    }
                    if violations.len() > 10 {
                        msg.push_str(&format!("  ... {} more\n", violations.len() - 10));
                    }
                    bail!(msg);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    struct AddModule(&'static str);
    impl Pass for AddModule {
        fn name(&self) -> &'static str {
            "add-module"
        }
        fn run(&self, d: &mut Design, ctx: &mut PassContext) -> Result<()> {
            d.add(Module::leaf(self.0, SourceFormat::Verilog, ""));
            ctx.namemap.record("add-module", "origin", self.0);
            Ok(())
        }
    }

    struct Corrupt;
    impl Pass for Corrupt {
        fn name(&self) -> &'static str {
            "corrupt"
        }
        fn run(&self, d: &mut Design, _: &mut PassContext) -> Result<()> {
            // Introduce a dangling module reference.
            let top = d.modules.get_mut(&d.top.clone()).unwrap();
            if top.is_grouped() {
                top.instances_mut().push(Instance::new("x", "Ghost"));
            }
            Ok(())
        }
    }

    fn base() -> Design {
        let mut d = Design::new("Top");
        d.add(Module::grouped("Top"));
        d
    }

    #[test]
    fn passes_run_in_order() {
        let mut d = base();
        let mut ctx = PassContext::new();
        PassManager::new()
            .add(AddModule("A"))
            .add(AddModule("B"))
            .run(&mut d, &mut ctx)
            .unwrap();
        assert!(d.module("A").is_some());
        assert!(d.module("B").is_some());
        assert_eq!(ctx.log.len(), 2);
        assert_eq!(ctx.namemap.trace("B"), "origin");
    }

    #[test]
    fn drc_hook_catches_corruption() {
        let mut d = base();
        let mut ctx = PassContext::new();
        let err = PassManager::new()
            .add(Corrupt)
            .run(&mut d, &mut ctx)
            .unwrap_err();
        assert!(err.to_string().contains("DRC failed after pass 'corrupt'"));
    }

    #[test]
    fn drc_hook_can_be_disabled() {
        let mut d = base();
        let mut ctx = PassContext::new();
        ctx.drc_after_each = false;
        PassManager::new().add(Corrupt).run(&mut d, &mut ctx).unwrap();
    }
}

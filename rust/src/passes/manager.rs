//! Pass framework: each transformation "does one thing and does it well"
//! (§3.3); the [`Pipeline`] sequences passes, keeps the original↔transformed
//! name mapping, optionally runs DRC after every pass, and records a
//! structured [`PipelineReport`] (per-pass wall time, DRC outcome, log
//! lines) for every run.

use crate::ir::core::Design;
use crate::ir::index::DesignIndex;
use crate::ir::namemap::NameMap;
use crate::ir::validate;
use anyhow::{bail, Context, Result};
use std::fmt;
use std::time::{Duration, Instant};

/// Severity of a [`Diagnostic`] emitted by a pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

/// One typed message emitted through a [`PassContext`]. The legacy
/// `ctx.log` string vector remains the plain-text view of the same
/// stream; diagnostics add the emitting pass and a severity so callers
/// (CLI, reports) can filter and attribute without string parsing.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable name of the pass that emitted it ("" outside a pipeline).
    pub pass: String,
    pub severity: Severity,
    pub message: String,
}

/// Shared state threaded through a pass pipeline.
///
/// `Clone` is deliberate: the flow snapshots the context after the
/// analysis stage ([`crate::coordinator::flow::AnalyzedDesign`]) so a
/// daemon can resume stages 3–4 from warm state — the clone carries the
/// log, name map, and the warm connectivity index.
#[derive(Debug, Clone)]
pub struct PassContext {
    pub namemap: NameMap,
    /// Run DRC after each pass and fail on violations.
    pub drc_after_each: bool,
    /// Human-readable log lines from passes.
    pub log: Vec<String>,
    /// Typed view of the log stream (plus warnings/errors).
    pub diagnostics: Vec<Diagnostic>,
    /// Cached ID-based connectivity over the design, built once per run
    /// and kept warm across passes that declare [`IndexPolicy::Tracked`].
    /// Passes query it via `ctx.index.conn(design, module)` and mutate
    /// modules through `ctx.index.edit` / announce adds with
    /// `ctx.index.touch` (see `ir::index` for the invalidation contract).
    pub index: DesignIndex,
    /// Shared module-characterization memo (the incremental re-flow
    /// engine's stage-1 cache). `None` — the default — recomputes from
    /// scratch; memo-aware passes (`platform-analyze`) produce identical
    /// bytes either way, the memo only changes wall time.
    pub chars: Option<std::sync::Arc<crate::eda::synth::CharMemo>>,
    /// Name of the pass currently running (set by [`Pipeline::run`]).
    current_pass: String,
}

impl Default for PassContext {
    /// Identical to [`PassContext::new`]: DRC-after-each-pass **on**.
    /// (Historically `Default` left it off, so contexts built with
    /// `..Default::default()` silently skipped DRC.)
    fn default() -> Self {
        Self::new()
    }
}

impl PassContext {
    pub fn new() -> PassContext {
        PassContext {
            namemap: NameMap::default(),
            drc_after_each: true,
            log: Vec::new(),
            diagnostics: Vec::new(),
            index: DesignIndex::new(),
            chars: None,
            current_pass: String::new(),
        }
    }

    /// The pass currently running under a [`Pipeline`], if any.
    pub fn current_pass(&self) -> &str {
        &self.current_pass
    }

    pub fn log(&mut self, msg: impl Into<String>) {
        self.diag(Severity::Info, msg.into());
    }

    pub fn warn(&mut self, msg: impl Into<String>) {
        self.diag(Severity::Warning, msg.into());
    }

    /// Record a typed [`Severity::Error`] diagnostic (e.g. a degraded
    /// step that used to panic, like connectivity on a leaf top).
    pub fn error(&mut self, msg: impl Into<String>) {
        self.diag(Severity::Error, msg.into());
    }

    fn diag(&mut self, severity: Severity, message: String) {
        self.log.push(match severity {
            Severity::Info => message.clone(),
            Severity::Warning => format!("warning: {message}"),
            Severity::Error => format!("error: {message}"),
        });
        self.diagnostics.push(Diagnostic {
            pass: self.current_pass.clone(),
            severity,
            message,
        });
    }
}

/// How a pass interacts with the cached connectivity index on
/// [`PassContext`]. The safe default, [`IndexPolicy::Invalidate`], drops
/// every cached entry after the pass runs; passes that route all
/// connectivity-affecting mutations through
/// [`DesignIndex::edit`] / [`DesignIndex::touch`] declare
/// [`IndexPolicy::Tracked`] and keep the caches warm across the
/// pipeline (debug builds cross-check every cache hit, so a wrong
/// `Tracked` claim fails loudly under `cargo test`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexPolicy {
    /// The pass maintains the index itself; caches survive it.
    Tracked,
    /// The pipeline invalidates all cached connectivity after the pass.
    Invalidate,
}

/// A composable IR transformation.
pub trait Pass {
    /// Stable name; the registry key used by `rsir pipeline <spec>`.
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `rsir passes`).
    fn description(&self) -> &'static str {
        "(undocumented pass)"
    }

    /// Whether this pass keeps `ctx.index` consistent itself. The
    /// conservative default forces a full invalidation after the pass;
    /// every in-tree pass overrides it with [`IndexPolicy::Tracked`].
    fn index_policy(&self) -> IndexPolicy {
        IndexPolicy::Invalidate
    }

    fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<()>;
}

/// DRC outcome recorded after one pass of a pipeline run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrcOutcome {
    /// `ctx.drc_after_each` was off — no check ran.
    Skipped,
    /// The design passed DRC after this pass. (A failing check aborts the
    /// pipeline with an error, so no record survives it.)
    Clean,
}

/// Instrumentation for one pass of a [`Pipeline`] run.
#[derive(Debug, Clone)]
pub struct PassRecord {
    pub name: String,
    /// Wall time of the pass itself (excluding the DRC check).
    pub wall: Duration,
    pub drc: DrcOutcome,
    /// Log lines emitted while this pass ran.
    pub log: Vec<String>,
}

/// Structured result of one [`Pipeline::run`]: what ran, for how long,
/// and what each pass reported. Purely observational — no pass *result*
/// depends on the recorded durations, so flows stay deterministic.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    /// Name of the pipeline that produced this report.
    pub pipeline: String,
    pub passes: Vec<PassRecord>,
    /// End-to-end wall time (passes + DRC checks).
    pub total: Duration,
}

impl PipelineReport {
    /// Pass names in execution order.
    pub fn pass_names(&self) -> Vec<&str> {
        self.passes.iter().map(|p| p.name.as_str()).collect()
    }

    /// Per-pass wall times aggregated by pass name (summing repeats,
    /// first-seen order) — the raw material for flow-level stats.
    pub fn timings(&self) -> Vec<(String, Duration)> {
        let mut out: Vec<(String, Duration)> = Vec::new();
        for p in &self.passes {
            match out.iter_mut().find(|(n, _)| *n == p.name) {
                Some((_, d)) => *d += p.wall,
                None => out.push((p.name.clone(), p.wall)),
            }
        }
        out
    }

    /// One-line breakdown, e.g. `rebuild 1.2ms | flatten 340µs`.
    pub fn render(&self) -> String {
        format!(
            "pipeline '{}': {} in {:.2?} ({})",
            self.pipeline,
            self.passes.len(),
            self.total,
            render_timings(&self.timings())
        )
    }
}

/// Shared `name wall | name wall` formatting for aggregated pass timings
/// ([`PipelineReport::render`], `FlowStats::render_passes`).
pub fn render_timings(timings: &[(String, Duration)]) -> String {
    timings
        .iter()
        .map(|(n, d)| format!("{n} {d:.2?}"))
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Run a sequence of passes with DRC hooks, recording a
/// [`PipelineReport`]. This is the single execution path for every
/// transformation in the repo — flows compose pipelines rather than
/// hand-calling `pass.run()`.
pub struct Pipeline {
    name: String,
    passes: Vec<Box<dyn Pass>>,
}

/// Former name of [`Pipeline`]; kept so `PassManager::new().add(..)`
/// call sites and docs keep working.
pub type PassManager = Pipeline;

impl fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Pipeline")
            .field("name", &self.name)
            .field(
                "passes",
                &self.passes.iter().map(|p| p.name()).collect::<Vec<_>>(),
            )
            .finish()
    }
}

impl Default for Pipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::named("pipeline")
    }

    pub fn named(name: impl Into<String>) -> Pipeline {
        Pipeline {
            name: name.into(),
            passes: Vec::new(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn add(self, pass: impl Pass + 'static) -> Self {
        self.add_boxed(Box::new(pass))
    }

    pub fn add_boxed(mut self, pass: Box<dyn Pass>) -> Self {
        self.passes.push(pass);
        self
    }

    pub fn len(&self) -> usize {
        self.passes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    pub fn run(&self, design: &mut Design, ctx: &mut PassContext) -> Result<PipelineReport> {
        let t_total = Instant::now();
        let mut records = Vec::with_capacity(self.passes.len());
        for pass in &self.passes {
            let log_start = ctx.log.len();
            ctx.current_pass = pass.name().to_string();
            let t_pass = Instant::now();
            let result = pass
                .run(design, ctx)
                .with_context(|| format!("pass '{}'", pass.name()));
            let wall = t_pass.elapsed();
            if let Err(e) = result {
                ctx.current_pass.clear();
                return Err(e);
            }
            ctx.log(format!("pass '{}' complete", pass.name()));
            match pass.index_policy() {
                IndexPolicy::Tracked => {}
                IndexPolicy::Invalidate => ctx.index.invalidate_all(),
            }
            let drc = if ctx.drc_after_each {
                let violations = validate::check_with(design, &mut ctx.index);
                if !violations.is_empty() {
                    let mut msg = format!("DRC failed after pass '{}':\n", pass.name());
                    for v in violations.iter().take(10) {
                        msg.push_str(&format!("  {v}\n"));
                    }
                    if violations.len() > 10 {
                        msg.push_str(&format!("  ... {} more\n", violations.len() - 10));
                    }
                    ctx.diag(Severity::Error, msg.clone());
                    ctx.current_pass.clear();
                    bail!(msg);
                }
                DrcOutcome::Clean
            } else {
                DrcOutcome::Skipped
            };
            ctx.current_pass.clear();
            records.push(PassRecord {
                name: pass.name().to_string(),
                wall,
                drc,
                log: ctx.log[log_start..].to_vec(),
            });
        }
        Ok(PipelineReport {
            pipeline: self.name.clone(),
            passes: records,
            total: t_total.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    struct AddModule(&'static str);
    impl Pass for AddModule {
        fn name(&self) -> &'static str {
            "add-module"
        }
        fn run(&self, d: &mut Design, ctx: &mut PassContext) -> Result<()> {
            d.add(Module::leaf(self.0, SourceFormat::Verilog, ""));
            ctx.namemap.record("add-module", "origin", self.0);
            Ok(())
        }
    }

    struct Corrupt;
    impl Pass for Corrupt {
        fn name(&self) -> &'static str {
            "corrupt"
        }
        fn run(&self, d: &mut Design, _: &mut PassContext) -> Result<()> {
            // Introduce a dangling module reference.
            let top = d.modules.get_mut(&d.top.clone()).unwrap();
            if top.is_grouped() {
                top.instances_mut().push(Instance::new("x", "Ghost"));
            }
            Ok(())
        }
    }

    fn base() -> Design {
        let mut d = Design::new("Top");
        d.add(Module::grouped("Top"));
        d
    }

    #[test]
    fn passes_run_in_order() {
        let mut d = base();
        let mut ctx = PassContext::new();
        let report = PassManager::new()
            .add(AddModule("A"))
            .add(AddModule("B"))
            .run(&mut d, &mut ctx)
            .unwrap();
        assert!(d.module("A").is_some());
        assert!(d.module("B").is_some());
        assert_eq!(ctx.log.len(), 2);
        assert_eq!(ctx.namemap.trace("B"), "origin");
        assert_eq!(report.pass_names(), ["add-module", "add-module"]);
        assert_eq!(report.passes[0].drc, DrcOutcome::Clean);
    }

    #[test]
    fn drc_hook_catches_corruption() {
        let mut d = base();
        let mut ctx = PassContext::new();
        let err = PassManager::new()
            .add(Corrupt)
            .run(&mut d, &mut ctx)
            .unwrap_err();
        assert!(err.to_string().contains("DRC failed after pass 'corrupt'"));
        // The failure is also a typed Error diagnostic.
        assert!(ctx
            .diagnostics
            .iter()
            .any(|d| d.severity == Severity::Error && d.pass == "corrupt"));
    }

    #[test]
    fn drc_hook_can_be_disabled() {
        let mut d = base();
        let mut ctx = PassContext::new();
        ctx.drc_after_each = false;
        let report = PassManager::new().add(Corrupt).run(&mut d, &mut ctx).unwrap();
        assert_eq!(report.passes[0].drc, DrcOutcome::Skipped);
    }

    #[test]
    fn tracked_pass_keeps_cache_warm_across_drc() {
        // A pass that mutates through the index keeps its caches: the
        // second DRC check hits the cache instead of rebuilding.
        struct AddWire;
        impl Pass for AddWire {
            fn name(&self) -> &'static str {
                "add-wire"
            }
            fn index_policy(&self) -> IndexPolicy {
                IndexPolicy::Tracked
            }
            fn run(&self, d: &mut Design, ctx: &mut PassContext) -> Result<()> {
                // The edit itself (even without a change) dirties the
                // cache — which is what this test exercises; the module
                // stays unchanged so DRC remains clean.
                let top_name = d.top.clone();
                ctx.index.edit(d, &top_name).unwrap();
                Ok(())
            }
        }
        struct Noop;
        impl Pass for Noop {
            fn name(&self) -> &'static str {
                "noop"
            }
            fn index_policy(&self) -> IndexPolicy {
                IndexPolicy::Tracked
            }
            fn run(&self, _: &mut Design, _: &mut PassContext) -> Result<()> {
                Ok(())
            }
        }
        let mut d = base();
        let mut ctx = PassContext::new();
        Pipeline::named("warm")
            .add(AddWire)
            .add(Noop)
            .run(&mut d, &mut ctx)
            .unwrap();
        // First DRC builds Top's connectivity (miss); the second DRC,
        // after the untouched Noop pass, is served from the cache (hit).
        let (hits, misses) = ctx.index.cache_stats();
        assert!(hits >= 1, "expected a cache hit, got {hits}/{misses}");
    }

    #[test]
    fn default_context_matches_new() {
        // Regression: `Default` used to leave drc_after_each = false,
        // silently skipping DRC in derived contexts.
        assert!(PassContext::default().drc_after_each);
        assert!(PassContext::new().drc_after_each);
    }

    #[test]
    fn diagnostics_attribute_to_running_pass() {
        struct Chatty;
        impl Pass for Chatty {
            fn name(&self) -> &'static str {
                "chatty"
            }
            fn run(&self, _: &mut Design, ctx: &mut PassContext) -> Result<()> {
                ctx.log("hello");
                ctx.warn("careful");
                Ok(())
            }
        }
        let mut d = base();
        let mut ctx = PassContext::new();
        let report = Pipeline::named("t").add(Chatty).run(&mut d, &mut ctx).unwrap();
        let hello = ctx.diagnostics.iter().find(|x| x.message == "hello").unwrap();
        assert_eq!(hello.pass, "chatty");
        assert_eq!(hello.severity, Severity::Info);
        assert!(ctx.log.contains(&"warning: careful".to_string()));
        // The pass's log lines are captured on its record.
        assert!(report.passes[0].log.contains(&"hello".to_string()));
    }
}

//! Flattened physical netlist: the leaf-instance graph the placer, router
//! and STA operate on.
//!
//! Elaborates the IR from the top module, aliasing nets across hierarchy
//! levels (a grouped module adds no logic, so its wires are pure aliases),
//! and emits one node per leaf instance and one edge per point-to-point
//! net between leaves. Clock/reset broadcast nets are excluded from the
//! edge list, matching the partitioning pass's connectivity analysis.
//!
//! Net identity is a dense `u32` key allocated during the walk: a parent
//! connection aliases the child port onto the parent's key, a locally
//! declared wire mints a fresh key — union by construction, with no
//! `"{scope}/{id}"` string paths to format, hash or compare. Edge
//! aggregation is commutative, so the resulting node/edge lists are
//! byte-identical to the historical string-keyed elaboration.

use crate::ir::core::*;
use crate::ir::digest::module_subtree_digests;
use crate::util::lru::{CacheStats, Lru};
use std::collections::BTreeMap;
use std::sync::Arc;

/// A leaf instance in the flattened design.
#[derive(Debug, Clone)]
pub struct FlatNode {
    /// Hierarchical path, e.g. "Layers_inst/L1".
    pub path: String,
    pub module: String,
    pub resources: Resources,
    /// Congestion-free internal critical path (ns).
    pub internal_ns: f64,
    /// True for relay stations / FF chains inserted by pipeline passes.
    pub is_pipeline: bool,
    /// Pre-assigned slot (from floorplan metadata), if any.
    pub fixed_slot: Option<String>,
}

/// A point-to-point net between two leaf instances.
#[derive(Debug, Clone)]
pub struct FlatEdge {
    pub src: usize,
    pub dst: usize,
    pub width: u64,
    /// Both endpoints sit on pipelinable interfaces.
    pub pipelinable: bool,
}

#[derive(Debug, Clone, Default)]
pub struct FlatNetlist {
    pub nodes: Vec<FlatNode>,
    pub edges: Vec<FlatEdge>,
}

impl FlatNetlist {
    pub fn total_resources(&self) -> Resources {
        self.nodes
            .iter()
            .fold(Resources::ZERO, |a, n| a.add(&n.resources))
    }

    pub fn node_index(&self, path: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.path == path)
    }
}

/// Provides per-leaf-module resources and internal delay — implemented by
/// `eda::synth` (metadata first, AST estimation as fallback).
pub trait ModuleCharacteristics {
    fn resources(&self, m: &Module) -> Resources;
    fn internal_ns(&self, m: &Module) -> f64;
}

/// Flatten `design` from its top module.
pub fn flatten(design: &Design, chars: &dyn ModuleCharacteristics) -> FlatNetlist {
    let mut fl = Flattener {
        design,
        chars,
        nodes: Vec::new(),
        pins: Vec::new(),
        nets: Vec::new(),
    };
    fl.walk(design.top_module(), "", &BTreeMap::new());
    fl.finish()
}

/// One leaf-port attachment to a global net.
#[derive(Debug, Clone)]
struct Pin {
    node: usize,
    dir: Dir,
    width: u32,
    pipelinable: bool,
    clockish: bool,
}

/// Dense global net key (index into `Flattener::nets`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct NetKey(u32);

struct Flattener<'a> {
    design: &'a Design,
    chars: &'a dyn ModuleCharacteristics,
    nodes: Vec<FlatNode>,
    pins: Vec<Pin>,
    /// net key -> pin indices (key allocation order).
    nets: Vec<Vec<usize>>,
}

impl<'a> Flattener<'a> {
    /// Global key of identifier `id` in the current scope: the parent's
    /// key when `id` is an aliased port, else a fresh key memoized in
    /// `local` (one per locally declared wire per scope).
    fn key_for(
        &mut self,
        id: &str,
        aliases: &BTreeMap<String, NetKey>,
        local: &mut BTreeMap<String, NetKey>,
    ) -> NetKey {
        if let Some(&k) = aliases.get(id) {
            return k;
        }
        *local.entry(id.to_string()).or_insert_with(|| {
            let k = NetKey(self.nets.len() as u32);
            self.nets.push(Vec::new());
            k
        })
    }

    /// `aliases` maps this module's port names to global net keys supplied
    /// by the parent; locally declared wires get fresh keys.
    fn walk(&mut self, m: &Module, scope: &str, aliases: &BTreeMap<String, NetKey>) {
        let mut local: BTreeMap<String, NetKey> = BTreeMap::new();
        for inst in m.instances() {
            let child_path = if scope.is_empty() {
                inst.instance_name.clone()
            } else {
                format!("{scope}/{}", inst.instance_name)
            };
            let Some(child) = self.design.module(&inst.module_name) else {
                continue;
            };
            // Map child ports to global nets.
            let mut child_aliases = BTreeMap::new();
            for conn in &inst.connections {
                if let ConnExpr::Id(id) = &conn.value {
                    let key = self.key_for(id, aliases, &mut local);
                    child_aliases.insert(conn.port.clone(), key);
                }
            }
            if child.is_grouped() {
                self.walk(child, &child_path, &child_aliases);
            } else {
                // Leaf: create a node and pins.
                let fixed_slot = inst
                    .metadata
                    .get("floorplan")
                    .and_then(|f| f.as_str())
                    .map(|s| s.to_string())
                    .or_else(|| {
                        child
                            .metadata
                            .get("floorplan")
                            .and_then(|f| f.as_str())
                            .map(|s| s.to_string())
                    });
                let node_idx = self.nodes.len();
                self.nodes.push(FlatNode {
                    path: child_path.clone(),
                    module: child.name.clone(),
                    resources: self.chars.resources(child),
                    internal_ns: self.chars.internal_ns(child),
                    is_pipeline: child
                        .metadata
                        .get("pipeline_element")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                    fixed_slot,
                });
                for conn in &inst.connections {
                    let Some(port) = child.port(&conn.port) else {
                        continue;
                    };
                    if let ConnExpr::Id(id) = &conn.value {
                        let key = self.key_for(id, aliases, &mut local);
                        let iface = child.interface_of(&port.name);
                        let pin = Pin {
                            node: node_idx,
                            dir: port.dir,
                            width: port.width,
                            pipelinable: iface.map(|i| i.pipelinable()).unwrap_or(false),
                            clockish: matches!(
                                iface,
                                Some(Interface::Clock { .. }) | Some(Interface::Reset { .. })
                            ),
                        };
                        let pidx = self.pins.len();
                        self.pins.push(pin);
                        self.nets[key.0 as usize].push(pidx);
                    }
                }
            }
        }
    }

    fn finish(self) -> FlatNetlist {
        // Cross-hierarchy aliasing already merged nets by construction
        // (aliased ports share the parent's key — an implicit ID-based
        // union); now aggregate pins per net into edges: for each net,
        // driver (Out pin) to each sink (In pin), summing multiple nets
        // between the same node pair. Sums and ANDs are commutative, so
        // iterating nets in key order instead of the historical
        // name order leaves every edge unchanged.
        let mut agg: BTreeMap<(usize, usize), (u64, bool, bool)> = BTreeMap::new();
        for pins in &self.nets {
            if pins.iter().any(|&p| self.pins[p].clockish) {
                continue;
            }
            let drivers: Vec<usize> = pins
                .iter()
                .copied()
                .filter(|&p| self.pins[p].dir == Dir::Out)
                .collect();
            let sinks: Vec<usize> = pins
                .iter()
                .copied()
                .filter(|&p| self.pins[p].dir == Dir::In)
                .collect();
            for &d in &drivers {
                for &s in &sinks {
                    let (dn, sn) = (self.pins[d].node, self.pins[s].node);
                    if dn == sn {
                        continue;
                    }
                    let pipe = self.pins[d].pipelinable && self.pins[s].pipelinable;
                    let e = agg.entry((dn, sn)).or_insert((0, true, false));
                    e.0 += self.pins[d].width as u64;
                    e.1 &= pipe;
                    e.2 = true;
                }
            }
        }
        let edges = agg
            .into_iter()
            .map(|((src, dst), (width, pipelinable, _))| FlatEdge {
                src,
                dst,
                width,
                pipelinable,
            })
            .collect();
        FlatNetlist {
            nodes: self.nodes,
            edges,
        }
    }
}

// ---------------------------------------------------------------------------
// Incremental flatten: per-module fragments memoized by subtree digest.
// ---------------------------------------------------------------------------

/// One leaf-port attachment inside a [`FlatFragment`] (node index is
/// fragment-local).
#[derive(Debug, Clone)]
struct FragPin {
    node: usize,
    dir: Dir,
    width: u32,
    pipelinable: bool,
    clockish: bool,
}

/// The flattened interior of one grouped module, expressed relative to
/// the module itself so it can be spliced into any instantiation site:
/// node paths are fragment-relative, and every net that reaches the
/// fragment root is kept *open* under its root-scope identifier — the
/// parent decides at splice time which of those its connections alias
/// onto parent nets (matching `Flattener::walk`, which aliases purely by
/// the parent's `conn.port` strings) and the rest become closed local
/// nets, exactly as an unaliased identifier mints a fresh key in `walk`.
#[derive(Debug, Clone, Default)]
struct FlatFragment {
    /// Leaf nodes in DFS instance order, paths relative to the fragment
    /// root.
    nodes: Vec<FlatNode>,
    /// Pins of nets open at the fragment root, keyed by root-scope
    /// identifier.
    open: BTreeMap<String, Vec<FragPin>>,
    /// Pins of nets fully internal to the fragment.
    closed: Vec<Vec<FragPin>>,
}

/// Warm state for [`flatten_incremental`]: fragments per module-subtree
/// digest plus whole netlists per top-subtree digest.
///
/// Keys cover the IR subtree only, **not** the characteristics provider —
/// a memo must always be driven with the same (pure) provider, which is
/// how `coordinator::memo::StageMemo` uses it.
#[derive(Debug)]
pub struct FlattenMemo {
    fragments: Lru<u64, Arc<FlatFragment>>,
    netlists: Lru<u64, Arc<FlatNetlist>>,
}

impl FlattenMemo {
    pub fn new(cap: usize) -> Self {
        FlattenMemo {
            fragments: Lru::new(cap),
            netlists: Lru::new(cap),
        }
    }

    /// (fragment cache, whole-netlist cache) counter snapshots.
    pub fn stats(&self) -> (CacheStats, CacheStats) {
        (self.fragments.stats(), self.netlists.stats())
    }
}

/// Flatten `design` from its top module, reusing fragments of any module
/// whose IR subtree digest is already in `memo`. Byte-identical to
/// [`flatten`] with the same provider: fragment splicing preserves the
/// DFS node order, and edge aggregation is commutative over nets, so the
/// assembled node and edge lists match element for element.
pub fn flatten_incremental(
    design: &Design,
    chars: &dyn ModuleCharacteristics,
    memo: &mut FlattenMemo,
) -> FlatNetlist {
    let digests = module_subtree_digests(design);
    let top_key = digests.get(&design.top).copied().unwrap_or(0);
    if let Some(nl) = memo.netlists.get(&top_key) {
        return (*nl).clone();
    }
    let frag = fragment_of(design, design.top_module(), &digests, chars, memo);
    let nl = netlist_of(&frag);
    memo.netlists.put(top_key, Arc::new(nl.clone()));
    nl
}

/// Memoized fragment of one module (leaf-top designs yield an empty
/// fragment: `instances()` is empty on leaves, as in `walk`).
fn fragment_of(
    design: &Design,
    m: &Module,
    digests: &BTreeMap<String, u64>,
    chars: &dyn ModuleCharacteristics,
    memo: &mut FlattenMemo,
) -> Arc<FlatFragment> {
    let key = digests.get(&m.name).copied().unwrap_or(0);
    if let Some(f) = memo.fragments.get(&key) {
        return f;
    }
    let mut frag = FlatFragment::default();
    for inst in m.instances() {
        let Some(child) = design.module(&inst.module_name) else {
            continue;
        };
        if child.is_grouped() {
            let cf = fragment_of(design, child, digests, chars, memo);
            splice(&mut frag, inst, &cf);
        } else {
            leaf_into(&mut frag, inst, child, chars);
        }
    }
    let frag = Arc::new(frag);
    memo.fragments.put(key, frag.clone());
    frag
}

/// Splice a child fragment into `frag` at instance `inst`: offset node
/// indices, prefix paths with the instance name, route the child's open
/// nets through the instance connections (last `Id` connection per port
/// wins, matching `child_aliases` insertion order in `walk`), and close
/// whatever the parent leaves unconnected.
fn splice(frag: &mut FlatFragment, inst: &Instance, child: &FlatFragment) {
    let off = frag.nodes.len();
    for n in &child.nodes {
        let mut n = n.clone();
        n.path = format!("{}/{}", inst.instance_name, n.path);
        frag.nodes.push(n);
    }
    let mut alias: BTreeMap<&str, &str> = BTreeMap::new();
    for conn in &inst.connections {
        if let ConnExpr::Id(id) = &conn.value {
            alias.insert(conn.port.as_str(), id.as_str());
        }
    }
    let shift = |pins: &[FragPin]| -> Vec<FragPin> {
        pins.iter()
            .map(|p| FragPin {
                node: p.node + off,
                ..p.clone()
            })
            .collect()
    };
    for (id, pins) in &child.open {
        match alias.get(id.as_str()) {
            Some(&parent_id) => frag
                .open
                .entry(parent_id.to_string())
                .or_default()
                .extend(shift(pins)),
            None => frag.closed.push(shift(pins)),
        }
    }
    for pins in &child.closed {
        frag.closed.push(shift(pins));
    }
}

/// Add one leaf instance to `frag` — the leaf arm of `walk` with an
/// empty scope.
fn leaf_into(
    frag: &mut FlatFragment,
    inst: &Instance,
    child: &Module,
    chars: &dyn ModuleCharacteristics,
) {
    let fixed_slot = inst
        .metadata
        .get("floorplan")
        .and_then(|f| f.as_str())
        .map(|s| s.to_string())
        .or_else(|| {
            child
                .metadata
                .get("floorplan")
                .and_then(|f| f.as_str())
                .map(|s| s.to_string())
        });
    let node_idx = frag.nodes.len();
    frag.nodes.push(FlatNode {
        path: inst.instance_name.clone(),
        module: child.name.clone(),
        resources: chars.resources(child),
        internal_ns: chars.internal_ns(child),
        is_pipeline: child
            .metadata
            .get("pipeline_element")
            .and_then(|v| v.as_bool())
            .unwrap_or(false),
        fixed_slot,
    });
    for conn in &inst.connections {
        let Some(port) = child.port(&conn.port) else {
            continue;
        };
        if let ConnExpr::Id(id) = &conn.value {
            let iface = child.interface_of(&port.name);
            frag.open.entry(id.clone()).or_default().push(FragPin {
                node: node_idx,
                dir: port.dir,
                width: port.width,
                pipelinable: iface.map(|i| i.pipelinable()).unwrap_or(false),
                clockish: matches!(
                    iface,
                    Some(Interface::Clock { .. }) | Some(Interface::Reset { .. })
                ),
            });
        }
    }
}

/// Aggregate a fragment's nets into a [`FlatNetlist`] — the same
/// commutative fold as `Flattener::finish`, so net iteration order is
/// output-irrelevant.
fn netlist_of(frag: &FlatFragment) -> FlatNetlist {
    let mut agg: BTreeMap<(usize, usize), (u64, bool)> = BTreeMap::new();
    for pins in frag.open.values().chain(frag.closed.iter()) {
        if pins.iter().any(|p| p.clockish) {
            continue;
        }
        for d in pins.iter().filter(|p| p.dir == Dir::Out) {
            for s in pins.iter().filter(|p| p.dir == Dir::In) {
                if d.node == s.node {
                    continue;
                }
                let e = agg.entry((d.node, s.node)).or_insert((0, true));
                e.0 += d.width as u64;
                e.1 &= d.pipelinable && s.pipelinable;
            }
        }
    }
    let edges = agg
        .into_iter()
        .map(|((src, dst), (width, pipelinable))| FlatEdge {
            src,
            dst,
            width,
            pipelinable,
        })
        .collect();
    FlatNetlist {
        nodes: frag.nodes.clone(),
        edges,
    }
}

#[cfg(test)]
pub mod test_support {
    use super::*;

    /// Characteristics provider reading only metadata, with fixed defaults.
    pub struct MetaChars;

    impl ModuleCharacteristics for MetaChars {
        fn resources(&self, m: &Module) -> Resources {
            crate::ir::builder::module_resources(m).unwrap_or(Resources::new(
                100.0, 100.0, 0.0, 0.0, 0.0,
            ))
        }
        fn internal_ns(&self, m: &Module) -> f64 {
            m.metadata
                .get("timing")
                .and_then(|t| t.at("internal_ns"))
                .and_then(|v| v.as_f64())
                .unwrap_or(2.5)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::MetaChars;
    use super::*;
    use crate::ir::builder::*;

    /// Top { a0: A, mid: Mid { b0: B } }, A.o(hs) -> (via top wire) Mid.i -> B.i
    fn hierarchical_design() -> Design {
        let a = LeafBuilder::verilog_stub("A")
            .clk_rst()
            .handshake("o", Dir::Out, 32)
            .resource(Resources::new(1000.0, 500.0, 0.0, 4.0, 0.0))
            .build();
        let b = LeafBuilder::verilog_stub("B")
            .clk_rst()
            .handshake("i", Dir::In, 32)
            .build();
        let mid = GroupedBuilder::new("Mid")
            .port("i", Dir::In, 32)
            .port("i_vld", Dir::In, 1)
            .port("i_rdy", Dir::Out, 1)
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .inst(
                "b0",
                "B",
                &[
                    ("i", "i"),
                    ("i_vld", "i_vld"),
                    ("i_rdy", "i_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        let top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .wire("d", 32)
            .wire("d_vld", 1)
            .wire("d_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("o", "d"),
                    ("o_vld", "d_vld"),
                    ("o_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .inst(
                "mid",
                "Mid",
                &[
                    ("i", "d"),
                    ("i_vld", "d_vld"),
                    ("i_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        let mut d = Design::new("Top");
        d.add(a);
        d.add(b);
        d.add(mid);
        d.add(top);
        d
    }

    #[test]
    fn flattens_across_hierarchy() {
        let d = hierarchical_design();
        let nl = flatten(&d, &MetaChars);
        assert_eq!(nl.nodes.len(), 2);
        assert!(nl.node_index("a0").is_some());
        assert!(nl.node_index("mid/b0").is_some());
    }

    #[test]
    fn edge_crosses_hierarchy_boundary() {
        let d = hierarchical_design();
        let nl = flatten(&d, &MetaChars);
        assert_eq!(nl.edges.len(), 2, "{:?}", nl.edges); // data+vld fwd, rdy back
        let a = nl.node_index("a0").unwrap();
        let b = nl.node_index("mid/b0").unwrap();
        let fwd = nl.edges.iter().find(|e| e.src == a && e.dst == b).unwrap();
        assert_eq!(fwd.width, 33); // 32 data + 1 valid
        assert!(fwd.pipelinable);
        let back = nl.edges.iter().find(|e| e.src == b && e.dst == a).unwrap();
        assert_eq!(back.width, 1); // ready
    }

    #[test]
    fn clock_nets_excluded() {
        let d = hierarchical_design();
        let nl = flatten(&d, &MetaChars);
        // No edge should have width > 33 (clk/rst fan-out would add more).
        assert!(nl.edges.iter().all(|e| e.width <= 33));
    }

    #[test]
    fn resources_read_from_metadata() {
        let d = hierarchical_design();
        let nl = flatten(&d, &MetaChars);
        let a = &nl.nodes[nl.node_index("a0").unwrap()];
        assert_eq!(a.resources.lut, 1000.0);
        assert_eq!(nl.total_resources().lut, 1100.0);
    }

    #[test]
    fn incremental_matches_full_on_hierarchy() {
        let d = hierarchical_design();
        let full = flatten(&d, &MetaChars);
        let mut memo = FlattenMemo::new(16);
        let inc = flatten_incremental(&d, &MetaChars, &mut memo);
        assert_eq!(format!("{full:?}"), format!("{inc:?}"));
        // A second run must hit the whole-netlist memo and stay identical.
        let again = flatten_incremental(&d, &MetaChars, &mut memo);
        assert_eq!(format!("{full:?}"), format!("{again:?}"));
        assert!(memo.stats().1.hits >= 1, "netlist memo should hit on rerun");
    }

    #[test]
    fn incremental_after_edit_matches_full() {
        let mut d = hierarchical_design();
        let mut memo = FlattenMemo::new(16);
        let _ = flatten_incremental(&d, &MetaChars, &mut memo);
        // Edit one leaf: the B fragment goes stale, Mid and Top follow,
        // but the warm A fragment is reused.
        let b = d.module_mut("B").unwrap();
        set_module_resources(b, Resources::new(777.0, 3.0, 0.0, 0.0, 0.0));
        let full = flatten(&d, &MetaChars);
        let inc = flatten_incremental(&d, &MetaChars, &mut memo);
        assert_eq!(format!("{full:?}"), format!("{inc:?}"));
    }

    #[test]
    fn incremental_matches_full_on_synthetic_designs() {
        use crate::designs::synthetic::{materialize, DesignGen};
        use crate::util::quickcheck::Gen;
        use crate::util::rng::Rng;
        let gen = DesignGen::default();
        for seed in 0..12 {
            let mut rng = Rng::new(seed);
            let d = materialize(&gen.generate(&mut rng));
            let full = flatten(&d, &MetaChars);
            let mut memo = FlattenMemo::new(32);
            let inc = flatten_incremental(&d, &MetaChars, &mut memo);
            assert_eq!(
                format!("{full:?}"),
                format!("{inc:?}"),
                "seed {seed} diverged"
            );
        }
    }

    #[test]
    fn floorplan_metadata_respected() {
        let mut d = hierarchical_design();
        let top = d.module_mut("Top").unwrap();
        top.instances_mut()[0]
            .metadata
            .insert("floorplan", crate::util::json::Json::str("SLOT_X0Y1"));
        let nl = flatten(&d, &MetaChars);
        let a = &nl.nodes[nl.node_index("a0").unwrap()];
        assert_eq!(a.fixed_slot.as_deref(), Some("SLOT_X0Y1"));
    }
}

//! Coarse static timing analysis over a placed flat netlist.
//!
//! Model: every leaf module registers its interface boundary (true for HLS
//! kernels, relay stations, and the RTL the benchmarks use), so each
//! inter-module net is a single register-to-register path:
//! `clk2q + wire(slotA, slotB, congestion) + setup`. Module-internal
//! critical paths scale with the congestion of their slot. Fmax is set by
//! the worst path; the report also carries per-slot utilization, total
//! wirelength, and boundary-wire overflow for the routability verdict.

use crate::device::model::VirtualDevice;
use crate::ir::core::Resources;
use crate::timing::delay::DelayModel;
use crate::timing::netlist::FlatNetlist;

/// Node-to-slot assignment (parallel to `FlatNetlist::nodes`).
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub slot_of_node: Vec<usize>,
}

impl Placement {
    pub fn new(slot_of_node: Vec<usize>) -> Placement {
        Placement { slot_of_node }
    }
}

/// One timing path in the report.
#[derive(Debug, Clone)]
pub struct PathInfo {
    pub description: String,
    pub delay_ns: f64,
}

#[derive(Debug, Clone)]
pub struct TimingReport {
    pub fmax_mhz: f64,
    pub critical_ns: f64,
    pub critical_path: PathInfo,
    /// Binding-resource utilization per slot.
    pub slot_util: Vec<f64>,
    /// Max slot utilization.
    pub max_util: f64,
    /// Σ edge width × slot distance (the floorplanner's objective).
    pub wirelength: f64,
    /// Demand / capacity per die-boundary column; >1 means overflow.
    pub boundary_load: Vec<f64>,
    pub routable: bool,
    pub unroutable_reason: Option<String>,
}

/// STA options: `unguided` models vendor placement without floorplan
/// guidance — interleaved, unrelated logic raises the *effective* routing
/// demand of a slot beyond its raw utilization (§2.2: unguided packing
/// "causes local routing congestion"). Floorplan-constrained placement
/// (the RIR flow) keeps partitions coherent, so no mixing penalty.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaOptions {
    pub unguided: bool,
}

/// Per-slot resource sums and (non-pipeline) member counts — the one
/// O(nodes) scan everything utilization-shaped derives from. Callers
/// that already hold per-slot usage (an explore sweep point, the delta
/// lane) compute utilization from an existing `SlotAggregates` via
/// [`SlotAggregates::effective`] instead of rescanning the netlist.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotAggregates {
    pub used: Vec<Resources>,
    pub count: Vec<usize>,
}

impl SlotAggregates {
    /// Collect aggregates with one pass over the nodes (in node order —
    /// the accumulation order is part of the bit-exactness contract the
    /// delta lane relies on).
    pub fn collect(nl: &FlatNetlist, placement: &Placement, dev: &VirtualDevice) -> Self {
        let mut used = vec![Resources::ZERO; dev.num_slots()];
        let mut count = vec![0usize; dev.num_slots()];
        for (n, node) in nl.nodes.iter().enumerate() {
            let s = placement.slot_of_node[n];
            used[s] = used[s].add(&node.resources);
            if !node.is_pipeline {
                count[s] += 1;
            }
        }
        SlotAggregates { used, count }
    }

    /// Per-slot effective utilization from precomputed aggregates — a
    /// pure per-slot map, so patching one slot's aggregate and re-mapping
    /// that slot is exact.
    pub fn effective(&self, dev: &VirtualDevice, opts: StaOptions) -> Vec<f64> {
        self.used
            .iter()
            .zip(&dev.slots)
            .zip(&self.count)
            .map(|((u, s), &c)| Self::effective_one(u, s, c, opts))
            .collect()
    }

    fn effective_one(
        used: &Resources,
        slot: &crate::device::model::Slot,
        count: usize,
        opts: StaOptions,
    ) -> f64 {
        let base = used.max_util(&slot.capacity);
        if opts.unguided && base > 0.0 && count > 1 {
            base + (0.015 * (count as f64 - 1.0)).min(0.18)
        } else {
            base
        }
    }
}

/// Per-slot utilization of the binding resource.
pub fn slot_utilization(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
) -> Vec<f64> {
    effective_utilization(nl, placement, dev, StaOptions::default())
}

/// Utilization including the unguided-placement mixing penalty:
/// +1.5 % effective routing demand per extra module interleaved in the
/// slot, capped at +18 %.
pub fn effective_utilization(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    opts: StaOptions,
) -> Vec<f64> {
    SlotAggregates::collect(nl, placement, dev).effective(dev, opts)
}

/// Demand on each die-boundary (boundary_index × column) in wires, as a
/// fraction of SLL capacity.
pub fn boundary_load(nl: &FlatNetlist, placement: &Placement, dev: &VirtualDevice) -> Vec<f64> {
    let nb = dev.die_rows.len();
    if nb == 0 {
        return Vec::new();
    }
    let mut demand = vec![0u64; nb * dev.cols];
    for e in &nl.edges {
        let sa = &dev.slots[placement.slot_of_node[e.src]];
        let sb = &dev.slots[placement.slot_of_node[e.dst]];
        let (lo, hi) = if sa.y <= sb.y { (sa.y, sb.y) } else { (sb.y, sa.y) };
        // Route vertically in the source column (L-shaped routing).
        let col = sa.x;
        for (bi, &brow) in dev.die_rows.iter().enumerate() {
            if lo <= brow && brow < hi {
                demand[bi * dev.cols + col] += e.width;
            }
        }
    }
    demand
        .iter()
        .map(|&d| d as f64 / dev.sll_per_column as f64)
        .collect()
}

/// Analyze a placed netlist (floorplan-guided placement assumed).
pub fn analyze(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
) -> TimingReport {
    analyze_with(nl, placement, dev, dm, StaOptions::default())
}

/// The expensive per-element intermediates of one STA run, cached by the
/// delta lane: per-slot aggregates and utilization, per-edge path delay,
/// per-node internal delay — plus fingerprints of everything they were
/// computed from, so [`analyze_delta`] can prove which entries survive
/// an edit. Assembling a [`TimingReport`] from terms (`fold_report`)
/// is cheap and recomputed every run; the terms are what delta reuse
/// buys.
#[derive(Debug, Clone)]
pub struct StaTerms {
    /// Device + delay model + options fingerprint; any mismatch forces a
    /// full recompute.
    env_fp: u64,
    /// FNV over (src, dst, width, pipelinable) per edge in order.
    edges_fp: u64,
    /// Per-node content signature (resources, internal_ns, is_pipeline —
    /// exactly the node fields the terms depend on).
    node_sig: Vec<u64>,
    /// Slot of each node when the terms were computed.
    slots: Vec<usize>,
    agg: SlotAggregates,
    util: Vec<f64>,
    edge_delay: Vec<f64>,
    node_delay: Vec<f64>,
}

fn env_fingerprint(dev: &VirtualDevice, dm: &DelayModel, opts: StaOptions) -> u64 {
    let mut f = crate::ir::digest::Fnv::new();
    f.write_u64(dev.fingerprint());
    f.write_f64(dm.clk2q_ns)
        .write_f64(dm.setup_ns)
        .write_f64(dm.local_ns)
        .write_f64(dm.hop_ns)
        .write_f64(dm.die_ns)
        .write_f64(dm.die_reg_ns)
        .write_f64(dm.cong_threshold)
        .write_f64(dm.cong_alpha)
        .write_f64(dm.route_fail_util)
        .write_f64(dm.min_clock_ns);
    f.write_bool(opts.unguided);
    f.finish()
}

fn edges_fingerprint(nl: &FlatNetlist) -> u64 {
    let mut f = crate::ir::digest::Fnv::new();
    for e in &nl.edges {
        f.write_usize(e.src)
            .write_usize(e.dst)
            .write_u64(e.width)
            .write_bool(e.pipelinable);
    }
    f.finish()
}

fn node_signatures(nl: &FlatNetlist) -> Vec<u64> {
    nl.nodes
        .iter()
        .map(|n| {
            let mut f = crate::ir::digest::Fnv::new();
            f.write_f64(n.resources.lut)
                .write_f64(n.resources.ff)
                .write_f64(n.resources.bram)
                .write_f64(n.resources.dsp)
                .write_f64(n.resources.uram)
                .write_f64(n.internal_ns)
                .write_bool(n.is_pipeline);
            f.finish()
        })
        .collect()
}

impl StaTerms {
    /// Compute every term from scratch.
    pub fn compute(
        nl: &FlatNetlist,
        placement: &Placement,
        dev: &VirtualDevice,
        dm: &DelayModel,
        opts: StaOptions,
    ) -> StaTerms {
        let agg = SlotAggregates::collect(nl, placement, dev);
        let util = agg.effective(dev, opts);
        let edge_delay = nl
            .edges
            .iter()
            .map(|e| {
                let (sa, sb) = (placement.slot_of_node[e.src], placement.slot_of_node[e.dst]);
                let registered = nl.nodes[e.src].is_pipeline || nl.nodes[e.dst].is_pipeline;
                dm.path_ns(dev, sa, sb, &util, registered)
            })
            .collect();
        let node_delay = nl
            .nodes
            .iter()
            .enumerate()
            .map(|(n, node)| dm.internal_ns(node.internal_ns, util[placement.slot_of_node[n]]))
            .collect();
        StaTerms {
            env_fp: env_fingerprint(dev, dm, opts),
            edges_fp: edges_fingerprint(nl),
            node_sig: node_signatures(nl),
            slots: placement.slot_of_node.clone(),
            agg,
            util,
            edge_delay,
            node_delay,
        }
    }

    /// Patch `prev` for the current inputs, recomputing only terms in
    /// *dirty slots* (slots a changed/moved node left or entered).
    /// Returns `None` when the delta preconditions fail (different
    /// environment, node count, or edge list) — the caller falls back to
    /// [`StaTerms::compute`]. Bit-exact: dirty-slot aggregates re-fold in
    /// node order, utilization is a pure per-slot map, and delays are
    /// pure functions of (slots, util, node content).
    pub fn patched(
        prev: &StaTerms,
        nl: &FlatNetlist,
        placement: &Placement,
        dev: &VirtualDevice,
        dm: &DelayModel,
        opts: StaOptions,
    ) -> Option<StaTerms> {
        if prev.env_fp != env_fingerprint(dev, dm, opts)
            || prev.node_sig.len() != nl.nodes.len()
            || prev.util.len() != dev.num_slots()
            || prev.edges_fp != edges_fingerprint(nl)
        {
            return None;
        }
        let node_sig = node_signatures(nl);
        let mut dirty_slot = vec![false; dev.num_slots()];
        let mut any = false;
        for n in 0..node_sig.len() {
            if node_sig[n] != prev.node_sig[n] || placement.slot_of_node[n] != prev.slots[n] {
                any = true;
                dirty_slot[prev.slots[n]] = true;
                dirty_slot[placement.slot_of_node[n]] = true;
            }
        }
        if !any {
            return Some(prev.clone());
        }
        let mut agg = prev.agg.clone();
        for (s, dirty) in dirty_slot.iter().enumerate() {
            if *dirty {
                agg.used[s] = Resources::ZERO;
                agg.count[s] = 0;
            }
        }
        for (n, node) in nl.nodes.iter().enumerate() {
            let s = placement.slot_of_node[n];
            if dirty_slot[s] {
                agg.used[s] = agg.used[s].add(&node.resources);
                if !node.is_pipeline {
                    agg.count[s] += 1;
                }
            }
        }
        let mut util = prev.util.clone();
        for (s, dirty) in dirty_slot.iter().enumerate() {
            if *dirty {
                util[s] =
                    SlotAggregates::effective_one(&agg.used[s], &dev.slots[s], agg.count[s], opts);
            }
        }
        let mut edge_delay = prev.edge_delay.clone();
        for (i, e) in nl.edges.iter().enumerate() {
            let (sa, sb) = (placement.slot_of_node[e.src], placement.slot_of_node[e.dst]);
            if dirty_slot[sa] || dirty_slot[sb] {
                let registered = nl.nodes[e.src].is_pipeline || nl.nodes[e.dst].is_pipeline;
                edge_delay[i] = dm.path_ns(dev, sa, sb, &util, registered);
            }
        }
        let mut node_delay = prev.node_delay.clone();
        for (n, node) in nl.nodes.iter().enumerate() {
            let s = placement.slot_of_node[n];
            if dirty_slot[s] {
                node_delay[n] = dm.internal_ns(node.internal_ns, util[s]);
            }
        }
        Some(StaTerms {
            env_fp: prev.env_fp,
            edges_fp: prev.edges_fp,
            node_sig,
            slots: placement.slot_of_node.clone(),
            agg,
            util,
            edge_delay,
            node_delay,
        })
    }
}

/// Analyze with explicit [`StaOptions`].
pub fn analyze_with(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
    opts: StaOptions,
) -> TimingReport {
    assert_eq!(nl.nodes.len(), placement.slot_of_node.len());
    let terms = StaTerms::compute(nl, placement, dev, dm, opts);
    fold_report(nl, placement, dev, dm, opts, &terms)
}

/// Delta lane: re-time only the cone touched since `prev` was computed.
/// Returns the report, the terms to cache for the next run, and whether
/// the delta path was actually taken (false = full recompute). The
/// report is byte-identical to [`analyze_with`] either way.
pub fn analyze_delta(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
    opts: StaOptions,
    prev: Option<&StaTerms>,
) -> (TimingReport, StaTerms, bool) {
    assert_eq!(nl.nodes.len(), placement.slot_of_node.len());
    let (terms, delta) =
        match prev.and_then(|p| StaTerms::patched(p, nl, placement, dev, dm, opts)) {
            Some(t) => (t, true),
            None => (StaTerms::compute(nl, placement, dev, dm, opts), false),
        };
    let report = fold_report(nl, placement, dev, dm, opts, &terms);
    (report, terms, delta)
}

/// Assemble a [`TimingReport`] from precomputed terms — the exact fold
/// the monolithic `analyze_with` used to run inline, so full and delta
/// lanes share one report path.
fn fold_report(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
    opts: StaOptions,
    terms: &StaTerms,
) -> TimingReport {
    let util = &terms.util;
    let mut critical = PathInfo {
        description: "(clock floor)".into(),
        delay_ns: dm.min_clock_ns,
    };
    let mut wirelength = 0.0f64;

    // Net paths.
    for (i, e) in nl.edges.iter().enumerate() {
        let (sa, sb) = (placement.slot_of_node[e.src], placement.slot_of_node[e.dst]);
        let d = terms.edge_delay[i];
        let (man, dies) = dev.slot_dist(sa, sb);
        wirelength += e.width as f64 * (man + dies) as f64;
        if d > critical.delay_ns {
            critical = PathInfo {
                description: format!(
                    "net {} -> {} ({}b, {} hops, {} die crossings)",
                    nl.nodes[e.src].path, nl.nodes[e.dst].path, e.width, man, dies
                ),
                delay_ns: d,
            };
        }
    }

    // Module-internal paths.
    for (n, node) in nl.nodes.iter().enumerate() {
        let u = util[placement.slot_of_node[n]];
        let d = terms.node_delay[n];
        if d > critical.delay_ns {
            critical = PathInfo {
                description: format!(
                    "internal {} ({} @ util {:.2})",
                    node.path, node.module, u
                ),
                delay_ns: d,
            };
        }
    }

    // Routability.
    let bload = boundary_load(nl, placement, dev);
    let max_util = util.iter().cloned().fold(0.0, f64::max);
    let mut routable = true;
    let mut reason = None;
    // Unguided placement cannot balance DSP columns: past ~38 % device-
    // wide DSP demand the router runs out of column-adjacent tracks (the
    // AutoBridge observation that duplicating compute without manual
    // floorplanning wrecks QoR — CNN 13x10/13x12 baselines in Table 2).
    let dsp_demand = nl.total_resources().dsp / dev.total_capacity().dsp.max(1.0);
    if opts.unguided && dsp_demand > 0.38 {
        routable = false;
        reason = Some(format!(
            "DSP column congestion: {:.0}% of device DSP without floorplan guidance",
            dsp_demand * 100.0
        ));
    } else if max_util > dm.route_fail_util {
        routable = false;
        let s = util
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        reason = Some(format!(
            "slot {} utilization {:.0}% exceeds {:.0}%",
            dev.slots[s].pblock,
            max_util * 100.0,
            dm.route_fail_util * 100.0
        ));
    } else if let Some((bi, &l)) = bload
        .iter()
        .enumerate()
        .find(|(_, &l)| l > 1.0)
    {
        routable = false;
        reason = Some(format!(
            "die-boundary column {} SLL overflow: {:.0}% of capacity",
            bi,
            l * 100.0
        ));
    }

    TimingReport {
        fmax_mhz: dm.fmax_mhz(critical.delay_ns),
        critical_ns: critical.delay_ns.max(dm.min_clock_ns),
        critical_path: critical,
        slot_util: util.clone(),
        max_util,
        wirelength,
        boundary_load: bload,
        routable,
        unroutable_reason: reason,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::timing::netlist::{FlatEdge, FlatNode, FlatNetlist};

    fn node(path: &str, lut: f64, internal: f64) -> FlatNode {
        FlatNode {
            path: path.into(),
            module: path.to_uppercase(),
            resources: Resources::new(lut, lut, 0.0, 0.0, 0.0),
            internal_ns: internal,
            is_pipeline: false,
            fixed_slot: None,
        }
    }

    fn two_node_netlist() -> FlatNetlist {
        FlatNetlist {
            nodes: vec![node("a", 10e3, 2.8), node("b", 10e3, 2.8)],
            edges: vec![FlatEdge {
                src: 0,
                dst: 1,
                width: 64,
                pipelinable: true,
            }],
        }
    }

    #[test]
    fn colocated_hits_internal_path() {
        let dev = builtin::by_name("u280").unwrap();
        let nl = two_node_netlist();
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(r.routable);
        // Internal 2.8 ns dominates the local net.
        assert!((r.critical_ns - 2.8).abs() < 1e-9, "{:?}", r.critical_path);
        assert!((r.fmax_mhz - 357.1).abs() < 1.0);
    }

    #[test]
    fn cross_die_unpipelined_is_critical() {
        let dev = builtin::by_name("u280").unwrap();
        let nl = two_node_netlist();
        let bottom = dev.slot_index(0, 0);
        let top = dev.slot_index(0, 2);
        let p = Placement::new(vec![bottom, top]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        // 2 die crossings unregistered: 0.15+0.45+2*2.3+0.1 = 5.3 ns
        assert!(r.critical_ns > 5.0, "{}", r.critical_ns);
        assert!(r.critical_path.description.contains("die crossings"));
        assert!(r.fmax_mhz < 200.0);
    }

    #[test]
    fn congestion_degrades_internal() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        // Load slot 0 to ~85% of its LUT capacity.
        let cap = dev.slots[0].capacity.lut;
        nl.nodes[0].resources.lut = cap * 0.85;
        nl.nodes[0].resources.ff = 0.0;
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(r.max_util > 0.84);
        assert!(r.critical_ns > 2.8 * 1.3, "{}", r.critical_ns);
    }

    #[test]
    fn overutilized_slot_unroutable() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        nl.nodes[0].resources.lut = dev.slots[0].capacity.lut * 0.95;
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(!r.routable);
        assert!(r.unroutable_reason.as_ref().unwrap().contains("utilization"));
    }

    #[test]
    fn sll_overflow_unroutable() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        nl.edges[0].width = dev.sll_per_column + 1;
        let bottom = dev.slot_index(0, 0);
        let top = dev.slot_index(0, 1);
        let p = Placement::new(vec![bottom, top]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(!r.routable);
        assert!(r.unroutable_reason.as_ref().unwrap().contains("SLL"));
    }

    /// Random netlist + placement pair for the delta differential.
    fn random_case(
        rng: &mut crate::util::rng::Rng,
        dev: &VirtualDevice,
    ) -> (FlatNetlist, Placement) {
        let n = 3 + rng.below(8);
        let nodes: Vec<FlatNode> = (0..n)
            .map(|i| {
                let mut nd = node(&format!("n{i}"), 1e3 + rng.f64() * 50e3, 1.5 + rng.f64() * 2.0);
                nd.is_pipeline = rng.below(5) == 0;
                nd
            })
            .collect();
        let edges = (0..n.saturating_sub(1))
            .map(|i| FlatEdge {
                src: i,
                dst: i + 1,
                width: 8 + rng.below(200) as u64,
                pipelinable: rng.below(2) == 0,
            })
            .collect();
        let slots = (0..n).map(|_| rng.below(dev.num_slots())).collect();
        (FlatNetlist { nodes, edges }, Placement::new(slots))
    }

    #[test]
    fn delta_matches_full_under_random_edits() {
        let dev = builtin::by_name("u280").unwrap();
        let dm = DelayModel::default();
        let mut rng = crate::util::rng::Rng::new(0xD1F7);
        for case in 0..24 {
            let (mut nl, mut p) = random_case(&mut rng, &dev);
            let opts = StaOptions {
                unguided: case % 2 == 0,
            };
            let (_, mut terms, _) = analyze_delta(&nl, &p, &dev, &dm, opts, None);
            for _ in 0..6 {
                // Random edit: move a node, retune a node, or no-op.
                match rng.below(3) {
                    0 => {
                        let i = rng.below(nl.nodes.len());
                        p.slot_of_node[i] = rng.below(dev.num_slots());
                    }
                    1 => {
                        let i = rng.below(nl.nodes.len());
                        nl.nodes[i].internal_ns += 0.25;
                        nl.nodes[i].resources.lut *= 1.1;
                    }
                    _ => {}
                }
                let full = analyze_with(&nl, &p, &dev, &dm, opts);
                let (delta, next, used_delta) =
                    analyze_delta(&nl, &p, &dev, &dm, opts, Some(&terms));
                assert!(used_delta, "delta preconditions should hold here");
                assert_eq!(format!("{full:?}"), format!("{delta:?}"), "case {case}");
                terms = next;
            }
        }
    }

    #[test]
    fn delta_falls_back_on_environment_change() {
        let dev = builtin::by_name("u280").unwrap();
        let dm = DelayModel::default();
        let nl = two_node_netlist();
        let p = Placement::new(vec![0, 1]);
        let (_, terms, _) = analyze_delta(&nl, &p, &dev, &dm, StaOptions::default(), None);
        // Different delay model → full recompute, still correct.
        let dm2 = DelayModel {
            hop_ns: 0.9,
            ..DelayModel::default()
        };
        let (rep, _, used_delta) =
            analyze_delta(&nl, &p, &dev, &dm2, StaOptions::default(), Some(&terms));
        assert!(!used_delta);
        let full = analyze_with(&nl, &p, &dev, &dm2, StaOptions::default());
        assert_eq!(format!("{full:?}"), format!("{rep:?}"));
    }

    #[test]
    fn delta_reuses_terms_on_identical_rerun() {
        let dev = builtin::by_name("u280").unwrap();
        let dm = DelayModel::default();
        let nl = two_node_netlist();
        let p = Placement::new(vec![0, 1]);
        let (first, terms, _) = analyze_delta(&nl, &p, &dev, &dm, StaOptions::default(), None);
        let (again, _, used_delta) =
            analyze_delta(&nl, &p, &dev, &dm, StaOptions::default(), Some(&terms));
        assert!(used_delta);
        assert_eq!(format!("{first:?}"), format!("{again:?}"));
    }

    #[test]
    fn wirelength_accumulates() {
        let dev = builtin::by_name("u250").unwrap();
        let nl = two_node_netlist();
        let a = dev.slot_index(0, 0);
        let b = dev.slot_index(1, 1);
        let p = Placement::new(vec![a, b]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        // manhattan 2 + 1 die crossing = 3 × 64b
        assert_eq!(r.wirelength, 192.0);
    }
}

//! Coarse static timing analysis over a placed flat netlist.
//!
//! Model: every leaf module registers its interface boundary (true for HLS
//! kernels, relay stations, and the RTL the benchmarks use), so each
//! inter-module net is a single register-to-register path:
//! `clk2q + wire(slotA, slotB, congestion) + setup`. Module-internal
//! critical paths scale with the congestion of their slot. Fmax is set by
//! the worst path; the report also carries per-slot utilization, total
//! wirelength, and boundary-wire overflow for the routability verdict.

use crate::device::model::VirtualDevice;
use crate::ir::core::Resources;
use crate::timing::delay::DelayModel;
use crate::timing::netlist::FlatNetlist;

/// Node-to-slot assignment (parallel to `FlatNetlist::nodes`).
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub slot_of_node: Vec<usize>,
}

impl Placement {
    pub fn new(slot_of_node: Vec<usize>) -> Placement {
        Placement { slot_of_node }
    }
}

/// One timing path in the report.
#[derive(Debug, Clone)]
pub struct PathInfo {
    pub description: String,
    pub delay_ns: f64,
}

#[derive(Debug, Clone)]
pub struct TimingReport {
    pub fmax_mhz: f64,
    pub critical_ns: f64,
    pub critical_path: PathInfo,
    /// Binding-resource utilization per slot.
    pub slot_util: Vec<f64>,
    /// Max slot utilization.
    pub max_util: f64,
    /// Σ edge width × slot distance (the floorplanner's objective).
    pub wirelength: f64,
    /// Demand / capacity per die-boundary column; >1 means overflow.
    pub boundary_load: Vec<f64>,
    pub routable: bool,
    pub unroutable_reason: Option<String>,
}

/// STA options: `unguided` models vendor placement without floorplan
/// guidance — interleaved, unrelated logic raises the *effective* routing
/// demand of a slot beyond its raw utilization (§2.2: unguided packing
/// "causes local routing congestion"). Floorplan-constrained placement
/// (the RIR flow) keeps partitions coherent, so no mixing penalty.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaOptions {
    pub unguided: bool,
}

/// Per-slot utilization of the binding resource.
pub fn slot_utilization(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
) -> Vec<f64> {
    effective_utilization(nl, placement, dev, StaOptions::default())
}

/// Utilization including the unguided-placement mixing penalty:
/// +1.5 % effective routing demand per extra module interleaved in the
/// slot, capped at +18 %.
pub fn effective_utilization(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    opts: StaOptions,
) -> Vec<f64> {
    let mut used = vec![Resources::ZERO; dev.num_slots()];
    let mut count = vec![0usize; dev.num_slots()];
    for (n, node) in nl.nodes.iter().enumerate() {
        let s = placement.slot_of_node[n];
        used[s] = used[s].add(&node.resources);
        if !node.is_pipeline {
            count[s] += 1;
        }
    }
    used.iter()
        .zip(&dev.slots)
        .zip(&count)
        .map(|((u, s), &c)| {
            let base = u.max_util(&s.capacity);
            if opts.unguided && base > 0.0 && c > 1 {
                base + (0.015 * (c as f64 - 1.0)).min(0.18)
            } else {
                base
            }
        })
        .collect()
}

/// Demand on each die-boundary (boundary_index × column) in wires, as a
/// fraction of SLL capacity.
pub fn boundary_load(nl: &FlatNetlist, placement: &Placement, dev: &VirtualDevice) -> Vec<f64> {
    let nb = dev.die_rows.len();
    if nb == 0 {
        return Vec::new();
    }
    let mut demand = vec![0u64; nb * dev.cols];
    for e in &nl.edges {
        let sa = &dev.slots[placement.slot_of_node[e.src]];
        let sb = &dev.slots[placement.slot_of_node[e.dst]];
        let (lo, hi) = if sa.y <= sb.y { (sa.y, sb.y) } else { (sb.y, sa.y) };
        // Route vertically in the source column (L-shaped routing).
        let col = sa.x;
        for (bi, &brow) in dev.die_rows.iter().enumerate() {
            if lo <= brow && brow < hi {
                demand[bi * dev.cols + col] += e.width;
            }
        }
    }
    demand
        .iter()
        .map(|&d| d as f64 / dev.sll_per_column as f64)
        .collect()
}

/// Analyze a placed netlist (floorplan-guided placement assumed).
pub fn analyze(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
) -> TimingReport {
    analyze_with(nl, placement, dev, dm, StaOptions::default())
}

/// Analyze with explicit [`StaOptions`].
pub fn analyze_with(
    nl: &FlatNetlist,
    placement: &Placement,
    dev: &VirtualDevice,
    dm: &DelayModel,
    opts: StaOptions,
) -> TimingReport {
    assert_eq!(nl.nodes.len(), placement.slot_of_node.len());
    let util = effective_utilization(nl, placement, dev, opts);

    let mut critical = PathInfo {
        description: "(clock floor)".into(),
        delay_ns: dm.min_clock_ns,
    };
    let mut wirelength = 0.0f64;

    // Net paths.
    for e in &nl.edges {
        let (sa, sb) = (placement.slot_of_node[e.src], placement.slot_of_node[e.dst]);
        let registered = nl.nodes[e.src].is_pipeline || nl.nodes[e.dst].is_pipeline;
        let d = dm.path_ns(dev, sa, sb, &util, registered);
        let (man, dies) = dev.slot_dist(sa, sb);
        wirelength += e.width as f64 * (man + dies) as f64;
        if d > critical.delay_ns {
            critical = PathInfo {
                description: format!(
                    "net {} -> {} ({}b, {} hops, {} die crossings)",
                    nl.nodes[e.src].path, nl.nodes[e.dst].path, e.width, man, dies
                ),
                delay_ns: d,
            };
        }
    }

    // Module-internal paths.
    for (n, node) in nl.nodes.iter().enumerate() {
        let u = util[placement.slot_of_node[n]];
        let d = dm.internal_ns(node.internal_ns, u);
        if d > critical.delay_ns {
            critical = PathInfo {
                description: format!(
                    "internal {} ({} @ util {:.2})",
                    node.path, node.module, u
                ),
                delay_ns: d,
            };
        }
    }

    // Routability.
    let bload = boundary_load(nl, placement, dev);
    let max_util = util.iter().cloned().fold(0.0, f64::max);
    let mut routable = true;
    let mut reason = None;
    // Unguided placement cannot balance DSP columns: past ~38 % device-
    // wide DSP demand the router runs out of column-adjacent tracks (the
    // AutoBridge observation that duplicating compute without manual
    // floorplanning wrecks QoR — CNN 13x10/13x12 baselines in Table 2).
    let dsp_demand = nl.total_resources().dsp / dev.total_capacity().dsp.max(1.0);
    if opts.unguided && dsp_demand > 0.38 {
        routable = false;
        reason = Some(format!(
            "DSP column congestion: {:.0}% of device DSP without floorplan guidance",
            dsp_demand * 100.0
        ));
    } else if max_util > dm.route_fail_util {
        routable = false;
        let s = util
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        reason = Some(format!(
            "slot {} utilization {:.0}% exceeds {:.0}%",
            dev.slots[s].pblock,
            max_util * 100.0,
            dm.route_fail_util * 100.0
        ));
    } else if let Some((bi, &l)) = bload
        .iter()
        .enumerate()
        .find(|(_, &l)| l > 1.0)
    {
        routable = false;
        reason = Some(format!(
            "die-boundary column {} SLL overflow: {:.0}% of capacity",
            bi,
            l * 100.0
        ));
    }

    TimingReport {
        fmax_mhz: dm.fmax_mhz(critical.delay_ns),
        critical_ns: critical.delay_ns.max(dm.min_clock_ns),
        critical_path: critical,
        slot_util: util,
        max_util,
        wirelength,
        boundary_load: bload,
        routable,
        unroutable_reason: reason,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::timing::netlist::{FlatEdge, FlatNode, FlatNetlist};

    fn node(path: &str, lut: f64, internal: f64) -> FlatNode {
        FlatNode {
            path: path.into(),
            module: path.to_uppercase(),
            resources: Resources::new(lut, lut, 0.0, 0.0, 0.0),
            internal_ns: internal,
            is_pipeline: false,
            fixed_slot: None,
        }
    }

    fn two_node_netlist() -> FlatNetlist {
        FlatNetlist {
            nodes: vec![node("a", 10e3, 2.8), node("b", 10e3, 2.8)],
            edges: vec![FlatEdge {
                src: 0,
                dst: 1,
                width: 64,
                pipelinable: true,
            }],
        }
    }

    #[test]
    fn colocated_hits_internal_path() {
        let dev = builtin::by_name("u280").unwrap();
        let nl = two_node_netlist();
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(r.routable);
        // Internal 2.8 ns dominates the local net.
        assert!((r.critical_ns - 2.8).abs() < 1e-9, "{:?}", r.critical_path);
        assert!((r.fmax_mhz - 357.1).abs() < 1.0);
    }

    #[test]
    fn cross_die_unpipelined_is_critical() {
        let dev = builtin::by_name("u280").unwrap();
        let nl = two_node_netlist();
        let bottom = dev.slot_index(0, 0);
        let top = dev.slot_index(0, 2);
        let p = Placement::new(vec![bottom, top]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        // 2 die crossings unregistered: 0.15+0.45+2*2.3+0.1 = 5.3 ns
        assert!(r.critical_ns > 5.0, "{}", r.critical_ns);
        assert!(r.critical_path.description.contains("die crossings"));
        assert!(r.fmax_mhz < 200.0);
    }

    #[test]
    fn congestion_degrades_internal() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        // Load slot 0 to ~85% of its LUT capacity.
        let cap = dev.slots[0].capacity.lut;
        nl.nodes[0].resources.lut = cap * 0.85;
        nl.nodes[0].resources.ff = 0.0;
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(r.max_util > 0.84);
        assert!(r.critical_ns > 2.8 * 1.3, "{}", r.critical_ns);
    }

    #[test]
    fn overutilized_slot_unroutable() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        nl.nodes[0].resources.lut = dev.slots[0].capacity.lut * 0.95;
        let p = Placement::new(vec![0, 0]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(!r.routable);
        assert!(r.unroutable_reason.as_ref().unwrap().contains("utilization"));
    }

    #[test]
    fn sll_overflow_unroutable() {
        let dev = builtin::by_name("u280").unwrap();
        let mut nl = two_node_netlist();
        nl.edges[0].width = dev.sll_per_column + 1;
        let bottom = dev.slot_index(0, 0);
        let top = dev.slot_index(0, 1);
        let p = Placement::new(vec![bottom, top]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        assert!(!r.routable);
        assert!(r.unroutable_reason.as_ref().unwrap().contains("SLL"));
    }

    #[test]
    fn wirelength_accumulates() {
        let dev = builtin::by_name("u250").unwrap();
        let nl = two_node_netlist();
        let a = dev.slot_index(0, 0);
        let b = dev.slot_index(1, 1);
        let p = Placement::new(vec![a, b]);
        let r = analyze(&nl, &p, &dev, &DelayModel::default());
        // manhattan 2 + 1 die crossing = 3 × 64b
        assert_eq!(r.wirelength, 192.0);
    }
}

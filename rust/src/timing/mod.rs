//! Timing substrate: the calibrated wire-delay model, the flattened
//! physical netlist, and coarse static timing analysis.

pub mod delay;
pub mod netlist;
pub mod sta;

pub use delay::DelayModel;
pub use netlist::{flatten, FlatEdge, FlatNetlist, FlatNode, ModuleCharacteristics};
pub use sta::{analyze, Placement, TimingReport};

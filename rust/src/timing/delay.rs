//! Calibrated wire-delay model of a multi-die FPGA.
//!
//! This is the core of the Vivado surrogate: the paper's claims are about
//! *relative* frequency (baseline vs HLPS-optimized), which hinge on three
//! physical effects the model captures (cf. §2.1 / Fig 2):
//!
//! 1. **Die crossings are expensive.** An unregistered SLL hop costs
//!    multiple nanoseconds; registering both ends hides most of it.
//! 2. **Distance costs.** Each slot-boundary hop adds routing delay.
//! 3. **Congestion degrades everything.** Once a slot's binding resource
//!    passes ~70 % utilization, detours inflate both net delay and the
//!    module-internal critical path, superlinearly.
//!
//! Constants are calibrated so the absolute numbers land in the ranges the
//! paper reports (vendor baselines 140–250 MHz, optimized 250–335 MHz);
//! see EXPERIMENTS.md for the calibration table.

use crate::device::model::VirtualDevice;

/// Tunable constants of the delay model.
#[derive(Debug, Clone)]
pub struct DelayModel {
    /// Register clock-to-out (ns).
    pub clk2q_ns: f64,
    /// Register setup (ns).
    pub setup_ns: f64,
    /// Net delay within one slot (ns).
    pub local_ns: f64,
    /// Extra delay per slot-boundary hop, same die (ns).
    pub hop_ns: f64,
    /// Extra delay per die crossing (ns) for ordinary logic-to-logic
    /// nets: the router reaches the SLL columns through general fabric,
    /// so unregistered crossings are expensive.
    pub die_ns: f64,
    /// Die crossing when the net terminates in a dedicated pipeline
    /// element (relay station / FF stage): the crossing uses the
    /// Laguna-registered SLL path (TX/RX flops at the boundary).
    pub die_reg_ns: f64,
    /// Utilization above which congestion kicks in.
    pub cong_threshold: f64,
    /// Quadratic congestion coefficient.
    pub cong_alpha: f64,
    /// Utilization above which the router gives up.
    pub route_fail_util: f64,
    /// Additional per-unit-width demand factor for boundary wires.
    pub min_clock_ns: f64,
}

impl Default for DelayModel {
    fn default() -> Self {
        DelayModel {
            clk2q_ns: 0.15,
            setup_ns: 0.10,
            local_ns: 0.45,
            hop_ns: 0.65,
            die_ns: 4.00,
            die_reg_ns: 1.90,
            cong_threshold: 0.68,
            cong_alpha: 14.0,
            route_fail_util: 0.92,
            // Hard floor from clock distribution (no FPGA runs at 2 GHz).
            min_clock_ns: 2.0,
        }
    }
}

impl DelayModel {
    /// Congestion multiplier for a slot at utilization `u` (of its binding
    /// resource). 1.0 below the threshold, quadratic above it:
    /// u = 0.80 → ≈1.20, u = 0.90 → ≈1.68.
    pub fn congestion_mult(&self, u: f64) -> f64 {
        let over = (u - self.cong_threshold).max(0.0);
        1.0 + self.cong_alpha * over * over
    }

    /// Raw (congestion-free) net delay between two slots. `registered`
    /// selects the Laguna-registered SLL rate for die crossings (nets
    /// terminating in a dedicated pipeline element).
    pub fn base_wire_ns(
        &self,
        dev: &VirtualDevice,
        slot_a: usize,
        slot_b: usize,
        registered: bool,
    ) -> f64 {
        let (manhattan, dies) = dev.slot_dist(slot_a, slot_b);
        // Die crossings are part of the manhattan distance; don't charge
        // the generic hop cost for the boundary row the SLL already spans.
        let plain_hops = manhattan.saturating_sub(dies);
        let die = if registered { self.die_reg_ns } else { self.die_ns };
        self.local_ns + self.hop_ns * plain_hops as f64 + die * dies as f64
    }

    /// Net delay between two slots under congestion. `util` holds the
    /// binding-resource utilization of every slot; the worst slot touched
    /// by the net (conservatively: both endpoints) scales the delay.
    pub fn wire_ns(
        &self,
        dev: &VirtualDevice,
        slot_a: usize,
        slot_b: usize,
        util: &[f64],
        registered: bool,
    ) -> f64 {
        let u = util[slot_a].max(util[slot_b]);
        self.base_wire_ns(dev, slot_a, slot_b, registered) * self.congestion_mult(u)
    }

    /// Full register-to-register path delay over one net.
    pub fn path_ns(
        &self,
        dev: &VirtualDevice,
        slot_a: usize,
        slot_b: usize,
        util: &[f64],
        registered: bool,
    ) -> f64 {
        self.clk2q_ns + self.wire_ns(dev, slot_a, slot_b, util, registered) + self.setup_ns
    }

    /// Module-internal critical path under congestion.
    pub fn internal_ns(&self, base_internal_ns: f64, slot_util: f64) -> f64 {
        base_internal_ns * self.congestion_mult(slot_util)
    }

    /// Convert a critical-path delay to MHz, clamped by the clock floor.
    pub fn fmax_mhz(&self, critical_ns: f64) -> f64 {
        1000.0 / critical_ns.max(self.min_clock_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;

    #[test]
    fn congestion_is_monotone_and_flat_below_threshold() {
        let dm = DelayModel::default();
        assert_eq!(dm.congestion_mult(0.3), 1.0);
        assert_eq!(dm.congestion_mult(0.68), 1.0);
        let m80 = dm.congestion_mult(0.80);
        let m90 = dm.congestion_mult(0.90);
        assert!(m80 > 1.1 && m80 < 1.4, "{m80}");
        assert!(m90 > m80);
    }

    #[test]
    fn die_crossing_dominates() {
        let dm = DelayModel::default();
        let dev = builtin::by_name("u280").unwrap();
        let a = dev.slot_index(0, 0);
        let b = dev.slot_index(0, 1); // one die crossing on u280
        let c = dev.slot_index(1, 0); // one horizontal hop, same die
        assert!(dm.base_wire_ns(&dev, a, b, false) > dm.base_wire_ns(&dev, a, c, false) + 1.0);
        assert!(dm.base_wire_ns(&dev, a, b, true) < dm.base_wire_ns(&dev, a, b, false));
    }

    #[test]
    fn local_net_is_cheap() {
        let dm = DelayModel::default();
        let dev = builtin::by_name("u250").unwrap();
        let u = vec![0.0; dev.num_slots()];
        let p = dm.path_ns(&dev, 0, 0, &u, false);
        // clk2q + local + setup
        assert!((p - 0.70).abs() < 1e-9);
        // supports > 600 MHz locally before the clock floor
        assert!(dm.fmax_mhz(p) >= 400.0);
    }

    #[test]
    fn unregistered_multi_die_path_is_slow() {
        let dm = DelayModel::default();
        let dev = builtin::by_name("u250").unwrap();
        let u = vec![0.0; dev.num_slots()];
        let bottom = dev.slot_index(0, 0);
        let top = dev.slot_index(1, 3);
        let p = dm.path_ns(&dev, bottom, top, &u, false);
        // 3 die crossings + 1 plain hop: deep into the 100-MHz range.
        assert!(p > 7.0, "{p}");
        assert!(dm.fmax_mhz(p) < 150.0);
    }

    #[test]
    fn fmax_clamped_by_clock_floor() {
        let dm = DelayModel::default();
        assert_eq!(dm.fmax_mhz(0.1), 500.0);
    }

    #[test]
    fn registered_die_hop_supports_300mhz() {
        // The whole point of HLPS: one pipelined die crossing per cycle
        // must comfortably beat 300 MHz.
        let dm = DelayModel::default();
        let dev = builtin::by_name("u280").unwrap();
        let u = vec![0.5; dev.num_slots()];
        let a = dev.slot_index(0, 0);
        let b = dev.slot_index(0, 1);
        let p = dm.path_ns(&dev, a, b, &u, true);
        assert!(dm.fmax_mhz(p) > 300.0, "die hop {p} ns");
    }
}

//! Incremental re-flow gates: the stage-memoization engine must never
//! change a single output byte, adjudicated differentially against
//! from-scratch runs.
//!
//! * [`seven_families_reflow_byte_identical`] — every design family runs
//!   the full [`oracle::check_incremental_reflow`] triple (cold through
//!   an empty memo, after a leaf edit through the polluted memo, and the
//!   original again through the doubly-polluted memo).
//! * [`fuzzed_reflow_smoke`] / [`fuzzed_reflow_deep`] — generated plans
//!   through the same oracle; the deep 64-case lane is `#[ignore]`d for
//!   the scheduled CI job (`rsir fuzz --reflow --cases 64` is the
//!   replayable equivalent).
//! * [`edit_script_reflow_matches_from_scratch`] — the property the
//!   engine exists for: a *sequence* of random leaf edits replayed
//!   through one long-lived memo, every step byte-identical to a
//!   from-scratch run, including the empty-edit and everything-dirty
//!   corners.

use rsir::coordinator::flow::{run_hlps_warm, FlowConfig, FlowWarm};
use rsir::coordinator::memo::StageMemo;
use rsir::designs::cnn::{self, CnnConfig};
use rsir::designs::{catapult, dynamatic, intel_hls, knn, llama2, minimap2};
use rsir::device::builtin;
use rsir::device::model::VirtualDevice;
use rsir::ir::core::Design;
use rsir::testing::{fuzz, oracle};
use rsir::util::json::{Json, JsonObj};
use rsir::util::rng::Rng;
use std::sync::Arc;

fn families() -> Vec<(&'static str, Design)> {
    vec![
        ("cnn", cnn::generate(&CnnConfig { rows: 4, cols: 4 }).unwrap().design),
        ("llama2", llama2::generate(&Default::default()).unwrap().design),
        ("minimap2", minimap2::generate().unwrap().design),
        ("knn", knn::generate(&Default::default()).unwrap().design),
        ("catapult", catapult::generate().unwrap().design),
        ("dynamatic", dynamatic::generate(dynamatic::EXAMPLES[0]).unwrap().design),
        ("intel_hls", intel_hls::generate(intel_hls::CHSTONE[0]).unwrap().design),
    ]
}

#[test]
fn seven_families_reflow_byte_identical() {
    for (name, design) in families() {
        let out = oracle::check_incremental_reflow(&design);
        assert!(out.is_clean(), "{name}: {}", out.render());
    }
}

#[test]
fn fuzzed_reflow_smoke() {
    let rep = fuzz::run_reflow(1, 8, &Default::default());
    assert!(rep.failure.is_none(), "{:?}", rep.failure);
}

/// The scheduled-CI depth (`rsir fuzz --reflow --seed 1 --cases 64`);
/// run locally with `cargo test -q --test reflow -- --ignored`.
#[test]
#[ignore]
fn fuzzed_reflow_deep() {
    let rep = fuzz::run_reflow(1, 64, &Default::default());
    assert!(rep.failure.is_none(), "{:?}", rep.failure);
}

/// Run the flow on a clone of `design`, optionally through `stage`, and
/// fingerprint the outcome (errors fold their rendered message, mirroring
/// the oracle's comparison).
fn flow_fp(
    design: &Design,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
    stage: Option<Arc<StageMemo>>,
) -> Result<u64, String> {
    let mut d = design.clone();
    let mut warm = FlowWarm {
        stage,
        ..Default::default()
    };
    match run_hlps_warm(&mut d, dev, cfg, &mut warm) {
        Ok(report) => Ok(oracle::flow_fingerprint(&d, &report)),
        Err(e) => Err(format!("{e:#}")),
    }
}

/// Bump `timing.internal_ns` of one named leaf by `delta`.
fn bump_leaf(d: &mut Design, name: &str, delta: f64) {
    let m = d.module_mut(name).unwrap();
    let old = m
        .metadata
        .get("timing")
        .and_then(|t| t.at("internal_ns"))
        .and_then(|j| j.as_f64())
        .unwrap_or(2.2);
    let mut t = JsonObj::new();
    t.insert("internal_ns", Json::num(old + delta));
    m.metadata.insert("timing", Json::Obj(t));
}

#[test]
fn edit_script_reflow_matches_from_scratch() {
    let dev = builtin::by_name("u250").unwrap();
    let cfg = FlowConfig {
        sa_refine: false,
        ..Default::default()
    };
    let mut design = cnn::generate(&CnnConfig { rows: 3, cols: 3 }).unwrap().design;
    let leaves: Vec<String> = design
        .modules
        .values()
        .filter(|m| !m.is_grouped())
        .map(|m| m.name.clone())
        .collect();
    assert!(!leaves.is_empty());

    let memo = Arc::new(StageMemo::new(64));
    let mut rng = Rng::new(17);
    for step in 0..6 {
        match step {
            // Step 0 primes the memo; step 1 is the empty edit — the
            // re-run of an unchanged design is the all-hit corner.
            0 | 1 => {}
            // Final step is the everything-dirty corner: every leaf
            // re-characterizes, every fragment rebuilds.
            5 => {
                for name in leaves.clone() {
                    bump_leaf(&mut design, &name, 0.05 + 0.9 * rng.f64());
                }
            }
            // Middle steps: one random leaf each.
            _ => {
                let name = leaves[rng.below(leaves.len())].clone();
                bump_leaf(&mut design, &name, 0.05 + 0.9 * rng.f64());
            }
        }
        let scratch = flow_fp(&design, &dev, &cfg, None);
        let warm = flow_fp(&design, &dev, &cfg, Some(memo.clone()));
        assert_eq!(warm, scratch, "step {step} diverged from from-scratch");
    }
    // The script actually exercised the incremental machinery: the
    // empty-edit step reused placements and delta STA at minimum.
    let stats = memo.stats();
    let get = |k: &str| stats.iter().find(|(n, _)| *n == k).unwrap().1;
    assert!(get("placements").hits >= 1, "{stats:?}");
    assert!(get("flat_netlists").hits >= 1, "{stats:?}");
    assert!(get("sta_delta").hits >= 1, "no delta STA run: {stats:?}");
}

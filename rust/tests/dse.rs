//! Integration tests for the multi-dimensional design-space explorer
//! (`coordinator::dse`):
//!
//! * the determinism contract — rows and Pareto front byte-identical at
//!   any worker count (1 vs 8), and with SA warm-starting on vs off;
//! * infeasible-point classification — a design too big for the device
//!   yields explicit unroutable rows, not an error (and never a fake
//!   routable row);
//! * degenerate sweeps — all-empty axes collapse to the single base
//!   point; a single-point sweep has a front of at most one row.

use rsir::coordinator::dse::{pareto_front, run_dse, DseConfig};
use rsir::coordinator::flow::{FlowConfig, PipelineStrategy};
use rsir::designs::cnn::{self, CnnConfig};
use rsir::device::builtin;
use rsir::util::pool::Pool;

fn small_cfg() -> DseConfig {
    DseConfig {
        utils: vec![0.55, 0.85],
        grids: vec![1, 2],
        sa_steps: vec![40, 80],
        strategies: vec![PipelineStrategy::Full],
        base: FlowConfig::default(),
        warm_sa: true,
    }
}

#[test]
fn rows_and_front_identical_at_any_worker_count() {
    let dev = builtin::by_name("u250").unwrap();
    let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
    let cfg = small_cfg();
    let serial = run_dse(&g.design, &dev, &cfg, &Pool::new(1)).unwrap();
    let wide = run_dse(&g.design, &dev, &cfg, &Pool::new(8)).unwrap();
    assert_eq!(serial.rows.len(), 8, "2 utils x 2 grids x 2 budgets");
    assert_eq!(serial.rows.len(), wide.rows.len());
    for (a, b) in serial.rows.iter().zip(&wide.rows) {
        assert!(a.bits_eq(b), "{a:?} vs {b:?}");
    }
    assert_eq!(serial.front.len(), wide.front.len());
    for (a, b) in serial.front.iter().zip(&wide.front) {
        assert!(a.bits_eq(b), "{a:?} vs {b:?}");
    }
    // The front is exactly the brute-force reference over the rows.
    let reference = pareto_front(&serial.rows);
    assert_eq!(serial.front.len(), reference.len());
    for (a, b) in serial.front.iter().zip(&reference) {
        assert!(a.bits_eq(b), "{a:?} vs {b:?}");
    }
    // Determinism extends to the rendered artifacts.
    assert_eq!(serial.render_front(), wide.render_front());
    assert_eq!(serial.to_json().pretty(), wide.to_json().pretty());
}

#[test]
fn warm_started_rows_equal_cold_bit_for_bit() {
    let dev = builtin::by_name("u250").unwrap();
    let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
    let warm_cfg = small_cfg();
    let cold_cfg = DseConfig {
        warm_sa: false,
        ..small_cfg()
    };
    let pool = Pool::new(2);
    let warm = run_dse(&g.design, &dev, &warm_cfg, &pool).unwrap();
    let cold = run_dse(&g.design, &dev, &cold_cfg, &pool).unwrap();
    assert_eq!(warm.rows.len(), cold.rows.len());
    for (a, b) in warm.rows.iter().zip(&cold.rows) {
        assert!(a.bits_eq(b), "{a:?} vs {b:?}");
    }
    assert_eq!(warm.to_json().pretty(), cold.to_json().pretty());
}

#[test]
fn infeasible_points_become_unroutable_rows() {
    // Far too big for the device at any limit (even the ILP's 0.90
    // relaxation ceiling): every point must come back as an explicit
    // unroutable row — typed infeasibility is a data point — the sweep
    // itself must succeed, and the front stays empty.
    let dev = builtin::by_name("u250").unwrap();
    let design = rsir::testing::oversized_chain(&dev, 12, 0.8);
    let cfg = DseConfig {
        utils: vec![0.5, 0.7],
        grids: vec![1],
        sa_steps: vec![40],
        strategies: vec![PipelineStrategy::Full],
        base: FlowConfig {
            sa_refine: false,
            ..Default::default()
        },
        warm_sa: true,
    };
    let report = run_dse(&design, &dev, &cfg, &Pool::new(2)).unwrap();
    assert_eq!(report.rows.len(), 2);
    for r in &report.rows {
        assert!(!r.routable, "{:?}", report.rows);
        assert!(r.wirelength.is_nan(), "{:?}", report.rows);
    }
    assert!(report.front.is_empty(), "{:?}", report.front);
}

#[test]
fn empty_axes_collapse_to_the_base_point() {
    let dev = builtin::by_name("u250").unwrap();
    let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
    let base = FlowConfig {
        sa_refine: false,
        ..Default::default()
    };
    let cfg = DseConfig {
        utils: vec![],
        grids: vec![],
        sa_steps: vec![],
        strategies: vec![],
        base: base.clone(),
        warm_sa: true,
    };
    let report = run_dse(&g.design, &dev, &cfg, &Pool::new(2)).unwrap();
    assert_eq!(report.rows.len(), 1);
    let p = &report.rows[0].point;
    assert_eq!(p.util_limit, base.util_limit);
    assert_eq!(p.grid, 1);
    assert_eq!(p.strategy, base.pipeline);
    assert_eq!(p.sa_steps, base.sa.steps);
    assert!(report.front.len() <= 1);
}

#[test]
fn duplicate_axis_values_do_not_duplicate_points() {
    let dev = builtin::by_name("u250").unwrap();
    let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
    let cfg = DseConfig {
        utils: vec![0.7, 0.7],
        grids: vec![1, 1],
        sa_steps: vec![40, 40],
        strategies: vec![PipelineStrategy::Full, PipelineStrategy::Full],
        base: FlowConfig {
            sa_refine: false,
            ..Default::default()
        },
        warm_sa: true,
    };
    let report = run_dse(&g.design, &dev, &cfg, &Pool::new(2)).unwrap();
    assert_eq!(report.rows.len(), 1, "{:?}", report.rows);
}

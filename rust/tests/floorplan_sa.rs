//! Differential and property gates for the incremental SA path (the PR-4
//! fuzzer pattern applied to floorplan scoring):
//!
//! * `ScoredState` stays in sync with `cost_scalar` under arbitrary
//!   move/swap/revert sequences on generated `Problem`s;
//! * a full `anneal` over the incremental evaluator is **identical**
//!   (best / best_cost / trace / evaluated) to the full-rescoring
//!   baseline for the same seed;
//! * results are byte-identical for 1 vs 8 SA workers (the PR-1 Table-2
//!   determinism pattern);
//! * NaN-poisoned evaluators can neither panic the explorer nor win.

use rsir::device::builtin;
use rsir::floorplan::cost::{BatchEvaluator, CostModel, CpuEvaluator, FullRescore, ScoredState};
use rsir::floorplan::problem::{Problem, Unit, UnitEdge};
use rsir::floorplan::sa::{anneal, SaConfig, SaResult};
use rsir::ir::core::Resources;
use rsir::util::quickcheck::{forall, Gen};
use rsir::util::rng::Rng;

/// Generator of floorplanning `Problem`s: a connected chain plus random
/// chords, integral resource vectors (the exact-friendly regime every
/// in-tree problem lives in — see the `ScoredState` exactness contract),
/// and occasional pinned units. Shrinks by dropping the last unit (with
/// its edges) or the last edge.
struct ProblemGen {
    max_units: usize,
}

impl Gen for ProblemGen {
    type Item = Problem;

    fn generate(&self, rng: &mut Rng) -> Problem {
        let n = rng.range(2, self.max_units);
        let units = (0..n)
            .map(|i| Unit {
                nodes: vec![i],
                resources: Resources::new(
                    (500 + rng.below(40_000)) as f64,
                    rng.below(30_000) as f64,
                    rng.below(48) as f64,
                    rng.below(128) as f64,
                    rng.below(8) as f64,
                ),
                // Every built-in device has >= 6 slots; pin within 4.
                fixed_slot: if rng.chance(0.1) {
                    Some(rng.below(4))
                } else {
                    None
                },
                name: format!("u{i}"),
            })
            .collect();
        let mut edges = Vec::new();
        for i in 0..n - 1 {
            edges.push(UnitEdge {
                a: i,
                b: i + 1,
                width: 16 * (1 + rng.below(16) as u64),
            });
            if rng.chance(0.3) {
                let j = rng.below(n);
                if j != i {
                    edges.push(UnitEdge {
                        a: i.min(j),
                        b: i.max(j),
                        width: 8 * (1 + rng.below(8) as u64),
                    });
                }
            }
        }
        Problem {
            units,
            edges,
            die_weight: 3.0,
        }
    }

    fn shrink(&self, p: &Problem) -> Vec<Problem> {
        let mut out = Vec::new();
        if p.units.len() > 2 {
            let n = p.units.len() - 1;
            let edges = p
                .edges
                .iter()
                .filter(|e| e.a < n && e.b < n)
                .cloned()
                .collect();
            out.push(Problem {
                units: p.units[..n].to_vec(),
                edges,
                die_weight: p.die_weight,
            });
        }
        if !p.edges.is_empty() {
            out.push(Problem {
                units: p.units.clone(),
                edges: p.edges[..p.edges.len() - 1].to_vec(),
                die_weight: p.die_weight,
            });
        }
        out
    }
}

fn results_identical(a: &SaResult, b: &SaResult) -> bool {
    a.best == b.best
        && a.best_cost.to_bits() == b.best_cost.to_bits()
        && a.trace == b.trace
        && a.evaluated == b.evaluated
}

#[test]
fn scored_state_tracks_cost_scalar_under_random_op_sequences() {
    let dev = builtin::by_name("u280").unwrap();
    let gen = ProblemGen { max_units: 24 };
    forall(0xF1, 48, &gen, |p| {
        let model = CostModel::build(p, &dev, 0.7, 1e-4);
        let n = p.units.len();
        let mut rng = Rng::new(99);
        let assign: Vec<usize> = (0..n).map(|_| rng.below(model.s)).collect();
        let mut st = ScoredState::new(&model, assign);
        let mut committed: Vec<usize> = st.assignment().to_vec();
        for _ in 0..120 {
            match rng.below(4) {
                0 => {
                    let u = rng.below(n);
                    let s = rng.below(model.s);
                    st.apply_move(&model, u, s);
                }
                1 if n >= 2 => {
                    let a = rng.below(n);
                    let b = (a + 1 + rng.below(n - 1)) % n;
                    st.apply_swap(&model, a, b);
                }
                2 => {
                    st.commit();
                    committed = st.assignment().to_vec();
                }
                _ => {
                    st.revert(&model);
                    if st.assignment() != &committed[..] {
                        return false;
                    }
                }
            }
            let want = model.cost_scalar(st.assignment());
            let got = st.cost(&model);
            if (got - want).abs() > 1e-3 * want.abs().max(1.0) {
                return false;
            }
        }
        true
    });
}

/// The differential oracle of the tentpole: the incremental lane must
/// reproduce the full-rescoring baseline *exactly* — same best, same
/// best_cost bits, same trace, same evaluation count — on generated
/// problems, with and without an ILP-style initial seed.
#[test]
fn incremental_anneal_identical_to_full_rescore() {
    let dev = builtin::by_name("u280").unwrap();
    let gen = ProblemGen { max_units: 16 };
    forall(0xD1F, 10, &gen, |p| {
        let model = CostModel::build(p, &dev, 0.7, 1e-4);
        let cfg = SaConfig {
            population: 6,
            proposals: 4,
            steps: 40,
            seed: 0xBEEF ^ p.units.len() as u64,
            ..Default::default()
        };
        let mut inc = CpuEvaluator {
            model: model.clone(),
        };
        let mut full = FullRescore(CpuEvaluator {
            model: model.clone(),
        });
        let a = anneal(p, &dev, &mut inc, None, &cfg);
        let b = anneal(p, &dev, &mut full, None, &cfg);
        if !results_identical(&a, &b) {
            return false;
        }
        // Seeded variant (chain 0 starts from a degenerate assignment).
        let init = vec![0usize; p.units.len()];
        let a = anneal(p, &dev, &mut inc, Some(&init), &cfg);
        let b = anneal(p, &dev, &mut full, Some(&init), &cfg);
        results_identical(&a, &b)
    });
}

/// PR-1 Table-2 pattern: the parallel-chains knob is wall-clock only.
#[test]
fn anneal_byte_identical_for_1_vs_8_workers() {
    let dev = builtin::by_name("u250").unwrap();
    let gen = ProblemGen { max_units: 20 };
    forall(0xCAFE, 6, &gen, |p| {
        let model = CostModel::build(p, &dev, 0.7, 1e-4);
        let mut results = Vec::new();
        for workers in [1usize, 8] {
            let cfg = SaConfig {
                steps: 60,
                workers,
                ..Default::default()
            };
            let mut ev = CpuEvaluator {
                model: model.clone(),
            };
            results.push(anneal(p, &dev, &mut ev, None, &cfg));
        }
        results_identical(&results[0], &results[1])
    });
}

/// An evaluator that poisons every 7th cost with NaN (and keeps no cost
/// model, forcing the batched lane — the lane that consumes raw
/// evaluator output). The explorer must stay total: no panic, and NaN
/// never beats a finite cost.
struct PoisonEvaluator {
    model: CostModel,
    count: usize,
}

impl BatchEvaluator for PoisonEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        batch
            .iter()
            .map(|c| {
                self.count += 1;
                if self.count % 7 == 0 {
                    f32::NAN
                } else {
                    self.model.cost_scalar(c)
                }
            })
            .collect()
    }
    fn name(&self) -> &'static str {
        "poison"
    }
}

#[test]
fn nan_costs_never_panic_and_never_win() {
    let dev = builtin::by_name("u280").unwrap();
    let gen = ProblemGen { max_units: 12 };
    forall(0xAB, 8, &gen, |p| {
        let model = CostModel::build(p, &dev, 0.7, 1e-4);
        let mut ev = PoisonEvaluator {
            model: model.clone(),
            count: 0,
        };
        let cfg = SaConfig {
            population: 4,
            proposals: 3,
            steps: 25,
            ..Default::default()
        };
        let r = anneal(p, &dev, &mut ev, None, &cfg);
        // With 4 chains only ~1 in 7 costs is NaN, so a finite best
        // exists; it must also genuinely score its assignment.
        r.best_cost.is_finite() && model.cost_scalar(&r.best).is_finite()
    });
}

#[test]
fn all_nan_evaluator_is_still_total() {
    struct AllNan;
    impl BatchEvaluator for AllNan {
        fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
            vec![f32::NAN; batch.len()]
        }
        fn name(&self) -> &'static str {
            "all-nan"
        }
    }
    let dev = builtin::by_name("u250").unwrap();
    let mut rng = Rng::new(4);
    let gen = ProblemGen { max_units: 8 };
    let p = gen.generate(&mut rng);
    let cfg = SaConfig {
        population: 3,
        proposals: 2,
        steps: 10,
        ..Default::default()
    };
    // Must terminate without panicking even though every cost is NaN.
    let r = anneal(&p, &dev, &mut AllNan, None, &cfg);
    assert!(r.best_cost.is_nan());
    assert_eq!(r.best.len(), p.units.len());
}

/// Pinned units survive the parallel incremental lane, and the merged
/// trace stays monotone non-increasing.
#[test]
fn parallel_lane_respects_pins_and_trace_monotonicity() {
    let dev = builtin::by_name("u280").unwrap();
    let mut rng = Rng::new(31);
    let gen = ProblemGen { max_units: 18 };
    for _ in 0..4 {
        let mut p = gen.generate(&mut rng);
        p.units[0].fixed_slot = Some(2);
        let model = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut ev = CpuEvaluator { model };
        let cfg = SaConfig {
            steps: 50,
            workers: 4,
            ..Default::default()
        };
        let r = anneal(&p, &dev, &mut ev, None, &cfg);
        assert_eq!(r.best[0], 2, "pinned unit moved");
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0]), "trace not monotone");
    }
}

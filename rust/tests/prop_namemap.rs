//! Property regression for the `NameMap::trace`/`chain` rename-cycle fix
//! (PR 3): random rename chains — including cycles produced by passes
//! renaming back and forth — must never loop forever, and `trace` must
//! stop exactly at the cycle entry (the first name encountered twice),
//! judged against an independently written brute-force reference.

use rsir::ir::namemap::NameMap;
use rsir::util::quickcheck::{forall, Gen};
use rsir::util::rng::Rng;

/// Random rename record lists over a 6-name alphabet; small enough that
/// cycles and self-renames are common.
struct RenameGen;

impl Gen for RenameGen {
    type Item = Vec<(u8, u8)>;

    fn generate(&self, rng: &mut Rng) -> Vec<(u8, u8)> {
        (0..rng.range(0, 12))
            .map(|_| (rng.below(6) as u8, rng.below(6) as u8))
            .collect()
    }

    fn shrink(&self, item: &Vec<(u8, u8)>) -> Vec<Vec<(u8, u8)>> {
        let mut out = Vec::new();
        if !item.is_empty() {
            out.push(item[..item.len() - 1].to_vec());
            out.push(item[1..].to_vec());
            out.push(item[..item.len() / 2].to_vec());
        }
        out
    }
}

fn name(i: u8) -> String {
    format!("n{i}")
}

fn build(records: &[(u8, u8)]) -> NameMap {
    let mut nm = NameMap::new();
    for (old, new) in records {
        nm.record("p", &name(*old), &name(*new));
    }
    nm
}

/// Brute-force reference: replay the `new -> old` map (latest record
/// wins, identity records dropped — mirroring `NameMap::record`), then
/// walk at most `len + 1` hops recording the visit order. By pigeonhole
/// that bound either reaches the origin or revisits a name; the expected
/// result is the origin, or the first name seen twice (the cycle entry).
fn reference_trace(records: &[(u8, u8)], start: &str) -> String {
    let mut parent = std::collections::BTreeMap::new();
    for (old, new) in records {
        if old != new {
            parent.insert(name(*new), name(*old));
        }
    }
    let mut visited = vec![start.to_string()];
    let mut cur = start.to_string();
    for _ in 0..=parent.len() {
        match parent.get(&cur) {
            None => return cur,
            Some(prev) => {
                if visited.contains(prev) {
                    return prev.clone();
                }
                visited.push(prev.clone());
                cur = prev.clone();
            }
        }
    }
    cur
}

#[test]
fn trace_matches_reference_on_random_chains_and_cycles() {
    forall(11, 300, &RenameGen, |records| {
        let nm = build(records);
        (0..6u8).all(|s| nm.trace(&name(s)) == reference_trace(records, &name(s)))
    });
}

#[test]
fn chain_terminates_and_lists_each_name_once() {
    forall(13, 300, &RenameGen, |records| {
        let nm = build(records);
        (0..6u8).all(|s| {
            // Termination is implied by returning at all; on a cycle the
            // chain must end at the cycle entry with no repeated names.
            let chain = nm.chain(&name(s));
            let mut seen = std::collections::BTreeSet::new();
            chain.len() <= records.len() + 1
                && chain[0].0 == name(s)
                && chain.iter().all(|(n, _)| seen.insert(n.clone()))
        })
    });
}

#[test]
fn known_cycle_regression_shape() {
    // The exact PR 3 regression: A -> B -> A, entered from outside.
    let nm = build(&[(0, 1), (1, 0), (0, 2)]); // A=n0, B=n1, C=n2
    assert_eq!(nm.trace("n2"), "n0", "must stop at the cycle entry");
    assert_eq!(nm.trace("n0"), "n0");
    assert_eq!(nm.trace("n1"), "n1");
}

//! Integration: full HLPS flows over every benchmark family, checking the
//! Table-2 shape invariants end-to-end (import → passes → floorplan →
//! pipeline → EDA backend), plus export validity of the optimized result.

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::device::builtin;
use rsir::ir::builder::LeafBuilder;
use rsir::ir::core::{Design, Dir, Resources};
use rsir::ir::validate;
use rsir::passes::registry;

fn quick() -> FlowConfig {
    FlowConfig {
        sa_refine: false,
        ..Default::default()
    }
}

#[test]
fn cnn_flow_beats_baseline_and_exports() {
    let dev = builtin::by_name("u250").unwrap();
    let g = rsir::designs::cnn::generate(&rsir::designs::cnn::CnnConfig { rows: 13, cols: 4 })
        .unwrap();
    let mut d = g.design;
    let report = run_hlps(&mut d, &dev, &quick()).unwrap();
    assert!(report.optimized.routable());
    let base = report.baseline_fmax().expect("13x4 baseline routable");
    assert!(
        report.optimized.fmax_mhz() > base * 1.2,
        "base {base:.0} vs {:.0}",
        report.optimized.fmax_mhz()
    );
    // Optimized design is still DRC-clean and exportable Verilog.
    validate::assert_clean(&d);
    let bundle = rsir::plugins::export(&d).unwrap();
    let top_v = bundle.file("design_top.v").unwrap();
    rsir::verilog::parse_file(top_v).unwrap();
    assert!(bundle.file("constraints.xdc").unwrap().contains("SLOT_X"));
}

#[test]
fn llama2_flow_on_new_device() {
    // New-platform portability (vp1552): same design, no code changes.
    let dev = builtin::by_name("vp1552").unwrap();
    let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
    let mut d = g.design;
    let report = run_hlps(&mut d, &dev, &quick()).unwrap();
    assert!(report.optimized.routable());
    assert!(report.relay_stations > 0);
    assert!(report.partitions > 5, "hierarchy must be decomposed");
    if let Some(imp) = report.improvement_pct() {
        assert!(imp > 0.0, "no regression: {imp:.0}%");
    }
}

#[test]
fn knn_unroutable_baseline_fixed_by_rir() {
    let dev = builtin::by_name("u280").unwrap();
    let g = rsir::designs::knn::generate(&Default::default()).unwrap();
    let mut d = g.design;
    let report = run_hlps(&mut d, &dev, &quick()).unwrap();
    assert!(report.baseline_fmax().is_none(), "KNN baseline must fail");
    assert!(report.optimized.routable(), "RIR must recover KNN");
    assert!(report.optimized.fmax_mhz() > 250.0);
}

#[test]
fn minimap2_small_gain_no_regression() {
    let dev = builtin::by_name("vp1552").unwrap();
    let g = rsir::designs::minimap2::generate().unwrap();
    let mut d = g.design;
    let report = run_hlps(&mut d, &dev, &quick()).unwrap();
    assert!(report.optimized.routable());
    if let Some(base) = report.baseline_fmax() {
        // Pre-pipelined design: small gain, but never a big loss.
        assert!(
            report.optimized.fmax_mhz() > base * 0.97,
            "regression: {base:.0} -> {:.0}",
            report.optimized.fmax_mhz()
        );
    }
}

#[test]
fn flow_deterministic() {
    let dev = builtin::by_name("u280").unwrap();
    let run = || {
        let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
        let mut d = g.design;
        let r = run_hlps(&mut d, &dev, &quick()).unwrap();
        (r.optimized.fmax_mhz(), r.relay_stations, r.partitions)
    };
    assert_eq!(run(), run());
}

#[test]
fn leaf_top_flow_degrades_with_typed_diagnostic_instead_of_panicking() {
    // A design whose top is a leaf has no block graph: stage 4 must skip
    // interconnect synthesis with a typed GraphError-backed diagnostic
    // (this used to panic), and the rest of the flow must complete.
    let mut d = Design::new("Solo");
    d.add(
        LeafBuilder::verilog_stub("Solo")
            .clk_rst()
            .handshake("i", Dir::In, 32)
            .resource(Resources::new(500.0, 400.0, 1.0, 2.0, 0.0))
            .build(),
    );
    let dev = builtin::by_name("u250").unwrap();
    let report = run_hlps(&mut d, &dev, &quick()).expect("leaf-top flow must not fail");
    assert_eq!(report.relay_stations, 0);
    assert_eq!(report.partitions, 0);
    let diag = report
        .log
        .iter()
        .find(|l| l.contains("interconnect synthesis skipped"))
        .expect("degraded-path diagnostic missing from flow log");
    // The diagnostic is typed Error severity and carries the GraphError.
    assert!(diag.starts_with("error:"), "{diag}");
    assert!(diag.contains("leaf module 'Solo'"), "{diag}");
    // The design is untouched structurally and still valid.
    validate::assert_clean(&d);
}

#[test]
fn pipeline_spec_errors_are_reported_with_context() {
    // The `rsir pipeline <spec>` surface: every malformed spec must fail
    // with an actionable message, never a panic or a late mystery error.
    let msg = |spec: &str| registry::build(spec).unwrap_err().to_string();

    let unknown = msg("definitely-not-a-pass");
    assert!(unknown.contains("unknown pass 'definitely-not-a-pass'"), "{unknown}");
    assert!(unknown.contains("registered:"), "{unknown}");

    let no_arg = msg("flatten=x");
    assert!(no_arg.contains("takes no argument"), "{no_arg}");

    let missing_arg = msg("rebuild-module");
    assert!(missing_arg.contains("requires an argument"), "{missing_arg}");

    let bad_shape = msg("group=oops");
    assert!(bad_shape.contains("PARENT/NAME"), "{bad_shape}");

    let empty_name = msg("flatten,,rebuild");
    assert!(empty_name.contains("empty pass name"), "{empty_name}");

    let empty_arg = msg("rebuild-module=");
    assert!(empty_arg.contains("empty argument"), "{empty_arg}");

    // And a well-formed spec still builds.
    assert_eq!(registry::build("flatten,iface-infer").unwrap().len(), 2);
}

#[test]
fn pjrt_flow_matches_cpu_flow_when_artifacts_exist() {
    if !rsir::runtime::artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dev = builtin::by_name("u280").unwrap();
    let mut cfg_cpu = FlowConfig::default();
    cfg_cpu.use_pjrt = false;
    cfg_cpu.sa.steps = 40;
    let mut cfg_pjrt = cfg_cpu.clone();
    cfg_pjrt.use_pjrt = true;
    let run = |cfg: &FlowConfig| {
        let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
        let mut d = g.design;
        run_hlps(&mut d, &dev, cfg).unwrap().optimized.fmax_mhz()
    };
    // Same seeds + bit-identical cost function => identical outcome.
    assert_eq!(run(&cfg_cpu), run(&cfg_pjrt));
}

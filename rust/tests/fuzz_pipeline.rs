//! Generative fuzzing of the whole pass pipeline against the
//! differential oracle suite (`rsir::testing::oracle`):
//!
//! * a fast bounded run (64 designs) gates tier-1 (`cargo test -q`);
//! * a 256-design run is `#[ignore]`d and executed by the scheduled CI
//!   fuzz job (`cargo test --release --test fuzz_pipeline -- --ignored`),
//!   which uploads the shrunken counterexample JSON on failure;
//! * mutation smoke checks prove the harness *can* fail: a deliberately
//!   broken pass is caught by at least one oracle invariant;
//! * seed-digest tests pin replayability: `rsir fuzz --seed N` always
//!   regenerates the same designs.

use rsir::designs::synthetic::{
    materialize, materialize_sources, BundleKind, BundleSpec, ChannelPlan, ChildRef, DesignGen,
    DesignPlan, GroupPlan, LeafPlan, LeafSource, SyntheticConfig, TopShape,
};
use rsir::ir::core::{ConnExpr, Dir, Instance};
use rsir::ir::validate;
use rsir::testing::{fuzz, oracle};
use rsir::util::quickcheck::{forall, Gen};
use rsir::util::rng::Rng;

/// The seed+size of the scheduled CI job — kept equal to the `rsir fuzz`
/// invocation in `.github/workflows/ci.yml` so failures replay 1:1.
const CI_SEED: u64 = 1;
const CI_CASES: usize = 256;

#[test]
fn tier1_fuzz_64_designs_through_full_oracle_suite() {
    forall(42, 64, &DesignGen::default(), |plan| {
        oracle::check_pipeline(&materialize(plan)).is_clean()
    });
}

#[test]
#[ignore = "scheduled CI fuzz: 256 designs (run with -- --ignored)"]
fn scheduled_fuzz_256_designs() {
    let rep = fuzz::run(CI_SEED, CI_CASES, &SyntheticConfig::default());
    if let Some(f) = rep.failure {
        // Drop the artifact where the CI workflow uploads it from.
        let _ = std::fs::write("../fuzz_counterexample.json", &f.minimal_json);
        panic!(
            "oracle failure at case {} (seed {CI_SEED}): {:?}\n\
             minimal violates {:?}; minimal plan:\n{:#?}",
            f.case, f.violations, f.minimal_violations, f.minimal_plan
        );
    }
}

#[test]
fn tier1_verilog_roundtrip_64_designs() {
    // The text path: every plan materialized as Verilog/manifest source,
    // imported, analyzed, exported and re-imported, under the three
    // round-trip invariants (verilog-fixpoint, import-bisimulation,
    // export-reimport). Replay any failure with
    // `rsir fuzz --verilog --seed 42 --cases 64`.
    forall(42, 64, &DesignGen::default(), |plan| {
        oracle::check_verilog_roundtrip(plan).is_clean()
    });
}

#[test]
#[ignore = "scheduled CI fuzz: 256 designs through the Verilog round-trip (run with -- --ignored)"]
fn scheduled_verilog_fuzz_256_designs() {
    let rep = fuzz::run_verilog(CI_SEED, CI_CASES, &SyntheticConfig::default());
    if let Some(f) = rep.failure {
        // Drop the artifact where the CI workflow uploads it from.
        let _ = std::fs::write("../fuzz_counterexample.v", &f.minimal_source);
        panic!(
            "round-trip failure at case {} (seed {CI_SEED}): {:?}\n\
             minimal violates {:?}; minimal plan:\n{:#?}",
            f.case, f.violations, f.minimal_violations, f.minimal_plan
        );
    }
}

#[test]
fn generated_designs_are_always_drc_clean() {
    // Generator soundness, independent of any pipeline: validity is by
    // construction, for original and shrunken plans alike.
    let gen = DesignGen::default();
    forall(9, 64, &gen, |plan| {
        validate::check(&materialize(plan)).is_empty()
            && gen
                .shrink(plan)
                .iter()
                .all(|q| validate::check(&materialize(q)).is_empty())
    });
}

#[test]
fn workers_1_vs_8_byte_identical() {
    let gen = DesignGen::default();
    let mut rng = Rng::new(7);
    let designs: Vec<_> = (0..8)
        .map(|_| materialize(&gen.generate(&mut rng)))
        .collect();
    let out = oracle::check_workers_equivalence(&designs);
    assert!(out.is_clean(), "{}", out.render());
}

/// Fixed two-channel design for the mutation smoke checks:
/// leaf0 {b0,b1: Out 32 hs} -> leaf1 {b0,b1: In 32 hs} inside grp0.
fn two_channel_plan() -> DesignPlan {
    let hs = |dir| BundleSpec {
        kind: BundleKind::Handshake,
        dir,
        width: 32,
    };
    DesignPlan {
        leaves: vec![
            LeafPlan {
                bundles: vec![hs(Dir::Out), hs(Dir::Out)],
                with_resource: false,
                multi_clock: false,
                source: LeafSource::Verilog,
            },
            LeafPlan {
                bundles: vec![hs(Dir::In), hs(Dir::In)],
                with_resource: false,
                multi_clock: false,
                source: LeafSource::Verilog,
            },
        ],
        groups: vec![GroupPlan {
            children: vec![ChildRef::Leaf(0), ChildRef::Leaf(1)],
            channels: vec![
                ChannelPlan {
                    src: 0,
                    src_bundle: 0,
                    dst: 1,
                    dst_bundle: 0,
                },
                ChannelPlan {
                    src: 0,
                    src_bundle: 1,
                    dst: 1,
                    dst_bundle: 1,
                },
            ],
            hint: false,
        }],
        with_empty: false,
        top: TopShape::Group,
    }
}

#[test]
fn mutation_smoke_drc_oracle_catches_dangling_reference() {
    // A "pass" that runs the real pipeline, then corrupts the design with
    // a dangling module reference. The DRC-preservation oracle must fire.
    let d = materialize(&two_channel_plan());
    let out = oracle::check_pipeline_with(&d, |d, ctx| {
        oracle::analyze_pipeline(d, ctx)?;
        let top = d.top.clone();
        ctx.index
            .edit(d, &top)
            .unwrap()
            .instances_mut()
            .push(Instance::new("ghost", "NoSuchModule"));
        Ok(())
    });
    assert!(!out.is_clean(), "broken pass escaped every oracle");
    assert!(
        out.violated().contains(&"drc-preserved"),
        "expected drc-preserved, got {:?}",
        out.violated()
    );
}

#[test]
fn mutation_smoke_bisimulation_catches_drc_clean_rewiring() {
    // Swap the consumer side of two width-identical channels: every net
    // still has two width-matched endpoints (DRC stays clean), but the
    // leaf-level connectivity changed — only bisimulation can see it.
    let d = materialize(&two_channel_plan());
    let out = oracle::check_pipeline_with(&d, |d, ctx| {
        oracle::analyze_pipeline(d, ctx)?;
        let top = d.top.clone();
        let m = ctx.index.edit(d, &top).unwrap();
        let c1 = m
            .instances_mut()
            .iter_mut()
            .find(|i| i.instance_name == "c1")
            .expect("consumer instance");
        for (port, wire) in [
            ("b0", "ch1"),
            ("b0_vld", "ch1_vld"),
            ("b0_rdy", "ch1_rdy"),
            ("b1", "ch0"),
            ("b1_vld", "ch0_vld"),
            ("b1_rdy", "ch0_rdy"),
        ] {
            *c1.connection_mut(port).expect(port) = ConnExpr::id(wire);
        }
        Ok(())
    });
    assert!(
        out.violated().contains(&"bisimulation"),
        "expected bisimulation, got {:?}",
        out.violated()
    );
    assert!(
        !out.violated().contains(&"drc-preserved"),
        "rewiring was supposed to stay DRC-clean: {}",
        out.render()
    );
}

#[test]
fn mutation_smoke_broken_printer_caught_by_fixpoint() {
    // A printer that silently renames every wire breaks the print→parse
    // AST fixpoint; the verilog-fixpoint invariant must fire even though
    // the renamed text is itself perfectly valid Verilog.
    let plan = two_channel_plan();
    let broken = |m: &rsir::verilog::ast::VModule| {
        let mut m2 = m.clone();
        for item in &mut m2.items {
            if let rsir::verilog::ast::VItem::Net(n) = item {
                for name in &mut n.names {
                    *name = format!("{name}_x");
                }
            }
        }
        rsir::verilog::printer::print_module(&m2)
    };
    let out = oracle::check_verilog_roundtrip_with(&plan, broken);
    assert!(
        out.violated().contains(&"verilog-fixpoint"),
        "expected verilog-fixpoint, got: {}",
        out.render()
    );
    // The production printer passes the same plan.
    let clean = oracle::check_verilog_roundtrip(&plan);
    assert!(clean.is_clean(), "{}", clean.render());
}

#[test]
fn lexer_and_parser_never_panic_on_mutated_printer_output() {
    // Hardened error paths: arbitrary byte-level corruption of valid
    // printed Verilog must yield `Err`, never a panic (no unwraps or
    // slicing crashes left in the lexer/parser).
    let srcs = materialize_sources(&two_channel_plan());
    let base = fuzz::render_sources(&srcs);
    let mut rng = Rng::new(99);
    for case in 0..200 {
        let mut bytes = base.clone().into_bytes();
        match rng.below(3) {
            0 => {
                // truncate at an arbitrary byte
                let cut = rng.below(bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // flip a byte to a printable ASCII char
                let at = rng.below(bytes.len());
                bytes[at] = 0x20 + rng.below(0x5f) as u8;
            }
            _ => {
                // delete a short span
                let at = rng.below(bytes.len());
                let len = (rng.below(16) + 1).min(bytes.len() - at);
                bytes.drain(at..at + len);
            }
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = rsir::verilog::parser::parse_file(&text);
            let _ = rsir::verilog::lexer::lex(&text);
        }));
        assert!(result.is_ok(), "case {case}: lexer/parser panicked on:\n{text}");
    }
}

#[test]
fn fuzz_driver_minimizes_an_injected_failure() {
    // End-to-end shrink machinery: a property that rejects any design
    // with a channel must minimize to a plan with very little else.
    let gen = DesignGen::default();
    let mut rng = Rng::new(33);
    let prop = |p: &DesignPlan| p.groups.iter().all(|g| g.channels.is_empty());
    let failing = loop {
        let p = gen.generate(&mut rng);
        if !prop(&p) {
            break p;
        }
    };
    let minimal = rsir::util::quickcheck::minimize(&gen, failing, &prop);
    let total_channels: usize = minimal.groups.iter().map(|g| g.channels.len()).sum();
    assert_eq!(total_channels, 1, "not minimal: {minimal:#?}");
    // The minimized plan still materializes to a valid design.
    assert!(validate::check(&materialize(&minimal)).is_empty());
}

#[test]
fn seed_digests_stable_and_distinct() {
    let cfg = SyntheticConfig::default();
    let a = fuzz::seed_digests(0..5, &cfg);
    let b = fuzz::seed_digests(0..5, &cfg);
    assert_eq!(a, b, "same seed must regenerate the same design");
    for i in 0..a.len() {
        for j in i + 1..a.len() {
            assert_ne!(a[i].1, a[j].1, "seeds {i} and {j} collide");
        }
    }
    // Cross-platform pin: when the golden file carries data lines,
    // digests must match it byte-for-byte. Regenerate with
    // `rsir fuzz --digests`. A file with only comments (or no file) means
    // "not pinned yet" — the in-process assertions above still gate.
    let golden = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/synthetic_digests.txt");
    let expected: Vec<(u64, u64)> = std::fs::read_to_string(&golden)
        .unwrap_or_default()
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(|l| {
            let (s, h) = l.split_once(' ').expect("format: <seed> <hex-digest>");
            (s.parse().unwrap(), u64::from_str_radix(h, 16).unwrap())
        })
        .collect();
    if expected.is_empty() {
        eprintln!("note: tests/golden/synthetic_digests.txt not pinned yet; current digests:");
        for (s, h) in &a {
            eprintln!("{s} {h:016x}");
        }
        // Scheduled CI runs with RSIR_REQUIRE_PINNED=1: there, an
        // unpinned golden file is a failure, not a note (the pin-digests
        // job commits the pin on the first push to main).
        assert!(
            std::env::var_os("RSIR_REQUIRE_PINNED").is_none(),
            "RSIR_REQUIRE_PINNED is set but the golden digest file carries no data lines"
        );
    } else {
        assert_eq!(a, expected, "seed digests drifted from the pinned golden file");
    }
}

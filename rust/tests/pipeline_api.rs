//! Integration tests for the unified pass-pipeline API: the registry
//! resolves every pass by stable name, pipeline specs parse/render
//! round-trip, pipeline runs are instrumented, and — the load-bearing
//! guarantee — the registry-backed `analyze_structure` pipeline is
//! behavior-identical to the direct hand-called pass sequence it
//! replaced, so Table 2 numbers are unchanged.

use rsir::coordinator::flow;
use rsir::coordinator::report;
use rsir::ir::core::Design;
use rsir::passes::iface_infer::InterfaceInference;
use rsir::passes::partition::PartitionAllAux;
use rsir::passes::passthrough::Passthrough;
use rsir::passes::rebuild::RebuildAll;
use rsir::passes::registry;
use rsir::passes::{Pass, PassContext};
use std::time::Duration;

#[test]
fn unknown_pass_name_is_an_error() {
    let err = registry::build("rebuild,flatten,bogus").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown pass 'bogus'"), "{msg}");
    // The error lists the registered names so the CLI is self-documenting.
    assert!(msg.contains("flatten"), "{msg}");
}

#[test]
fn registry_lists_all_passes_and_pipelines() {
    let names: Vec<&str> = registry::passes().iter().map(|e| e.name).collect();
    // The nine §3.3 passes plus the pass-ified platform analyzer.
    for expected in [
        "flatten",
        "group",
        "iface-infer",
        "partition",
        "partition-aux",
        "passthrough",
        "platform-analyze",
        "rebuild",
        "rebuild-module",
        "relay-insert",
    ] {
        assert!(names.contains(&expected), "registry missing '{expected}'");
    }
    assert_eq!(names.len(), 10);
    assert!(registry::pipelines()
        .iter()
        .any(|p| p.name == registry::ANALYZE_STRUCTURE));
    // Every registered pipeline builds.
    for p in registry::pipelines() {
        assert!(!registry::named(p.name).unwrap().is_empty());
    }
}

#[test]
fn pipeline_spec_parse_round_trip() {
    let spec = " rebuild , rebuild-module=LLM ,iface-infer,group=Top/G/a+b ,flatten";
    let parsed = registry::parse_spec(spec).unwrap();
    assert_eq!(parsed.len(), 5);
    assert_eq!(parsed[1].name, "rebuild-module");
    assert_eq!(parsed[1].arg.as_deref(), Some("LLM"));
    assert_eq!(parsed[2].arg, None);
    let canonical = registry::render_spec(&parsed);
    assert_eq!(
        canonical,
        "rebuild,rebuild-module=LLM,iface-infer,group=Top/G/a+b,flatten"
    );
    // Round-trip: parsing the rendering reproduces the invocations.
    assert_eq!(registry::parse_spec(&canonical).unwrap(), parsed);
    // Degenerate specs are rejected.
    assert!(registry::parse_spec("rebuild,,flatten").is_err());
    assert!(registry::parse_spec("=x").is_err());
    assert!(registry::parse_spec("rebuild-module=").is_err());
}

#[test]
fn pipeline_run_populates_per_pass_timing() {
    let g = rsir::designs::cnn::generate(&rsir::designs::cnn::CnnConfig { rows: 4, cols: 4 })
        .unwrap();
    let mut d = g.design;
    let mut ctx = PassContext::new();
    ctx.drc_after_each = false;
    let report = registry::named(registry::ANALYZE_STRUCTURE)
        .unwrap()
        .run(&mut d, &mut ctx)
        .unwrap();
    assert_eq!(
        report.pass_names(),
        [
            "platform-analyze",
            "rebuild",
            "iface-infer",
            "partition-aux",
            "passthrough",
            "iface-infer",
            "platform-analyze",
            "flatten",
        ]
    );
    // Timing is populated: the run took nonzero time, every pass record
    // fits inside it, and repeated passes aggregate under one name.
    assert!(report.total > Duration::ZERO);
    assert!(report.passes.iter().all(|p| p.wall <= report.total));
    let timings = report.timings();
    assert_eq!(timings.len(), 6); // 8 runs, 2 repeated names
    assert_eq!(timings[0].0, "platform-analyze");
    let sum: Duration = report.passes.iter().map(|p| p.wall).sum();
    assert!(sum <= report.total);
    // Each record carries the log lines its pass emitted (at minimum the
    // completion line the pipeline itself appends).
    assert!(report.passes.iter().all(|p| !p.log.is_empty()));
}

/// The hand-called pass sequence `analyze_structure` used before the
/// registry existed. Kept verbatim here as the reference semantics.
fn analyze_structure_direct(design: &mut Design, ctx: &mut PassContext) {
    rsir::plugins::platform::analyze(design);
    RebuildAll.run(design, ctx).unwrap();
    InterfaceInference.run(design, ctx).unwrap();
    PartitionAllAux.run(design, ctx).unwrap();
    Passthrough.run(design, ctx).unwrap();
    InterfaceInference.run(design, ctx).unwrap();
    rsir::plugins::platform::analyze(design);
    rsir::passes::flatten::Flatten.run(design, ctx).unwrap();
}

#[test]
fn pipeline_analyze_matches_direct_pass_calls() {
    // Same generated design through both paths -> byte-identical IR,
    // which is what keeps every downstream Table 2 number unchanged.
    let make = || {
        rsir::designs::llama2::generate(&Default::default())
            .unwrap()
            .design
    };
    let mut direct = make();
    let mut ctx_direct = PassContext::new();
    ctx_direct.drc_after_each = false;
    analyze_structure_direct(&mut direct, &mut ctx_direct);

    let mut piped = make();
    let mut ctx_piped = PassContext::new();
    ctx_piped.drc_after_each = false;
    flow::analyze_structure(&mut piped, &mut ctx_piped).unwrap();

    assert_eq!(direct, piped);
    // The namemap (original <-> transformed names) covers the same
    // renames, and every flattened instance traces to the same origin.
    assert_eq!(ctx_direct.namemap.len(), ctx_piped.namemap.len());
    for inst in piped.top_module().instances() {
        assert_eq!(
            ctx_direct.namemap.trace(&inst.instance_name),
            ctx_piped.namemap.trace(&inst.instance_name)
        );
    }
}

#[test]
fn pipeline_based_run_hlps_is_byte_deterministic() {
    // The seed's Table 2 determinism contract survives the re-routing of
    // stages 1-2 through the registry-backed pipeline: two runs render
    // byte-for-byte identically.
    let cfg = flow::FlowConfig {
        sa_refine: false,
        ..Default::default()
    };
    let render = || {
        let row = report::run_row("CNN 13x4", "cnn:13x4", "u250", &cfg).unwrap();
        report::render_table2(&[row]).to_string()
    };
    assert_eq!(render(), render());
}

//! Index/graph equivalence gates for the interned, indexed IR core:
//!
//! 1. For every built-in benchmark design, the indexed connectivity view
//!    (`DesignIndex::conn` → `ModuleConn::to_block_graph`) matches a
//!    reference reimplementation of the legacy string-keyed
//!    `BlockGraph::build` net-for-net — before *and* after the analysis
//!    pipeline has run (i.e. through real cache invalidations).
//! 2. Running the analysis pipeline with connectivity caching disabled
//!    produces byte-identical IR JSON, logs and name maps — the cache is
//!    purely an accelerator.
//! 3. A full `run_hlps` flow through the index stays byte-deterministic:
//!    IR JSON and the rendered Table 2 row are identical across runs.

use rsir::coordinator::flow;
use rsir::coordinator::report;
use rsir::designs::Generated;
use rsir::ir::core::*;
use rsir::ir::graph::{BlockGraph, Endpoint, NetInfo};
use rsir::ir::index::DesignIndex;
use rsir::ir::schema::design_to_json;
use rsir::passes::PassContext;
use std::collections::BTreeMap;

/// The legacy string-keyed graph construction, kept verbatim as the
/// reference semantics (the in-tree `BlockGraph::build` is now a view
/// over `ModuleConn`, so the comparison must be against an independent
/// implementation).
fn reference_block_graph(m: &Module) -> BlockGraph {
    let mut nets: BTreeMap<String, NetInfo> = BTreeMap::new();
    for w in m.wires() {
        nets.entry(w.name.clone()).or_default().width = w.width;
    }
    for p in &m.ports {
        let e = nets.entry(p.name.clone()).or_default();
        e.width = p.width;
        e.endpoints.push(Endpoint::Parent {
            port: p.name.clone(),
        });
    }
    let mut instances = Vec::new();
    for inst in m.instances() {
        instances.push(inst.instance_name.clone());
        for conn in &inst.connections {
            if let ConnExpr::Id(id) = &conn.value {
                nets.entry(id.clone()).or_default().endpoints.push(Endpoint::Inst {
                    inst: inst.instance_name.clone(),
                    port: conn.port.clone(),
                });
            }
        }
    }
    BlockGraph { nets, instances }
}

/// One generator per built-in benchmark family (small configs where the
/// family is parameterized). The second tuple field says whether the
/// family also goes through the analysis pipeline in this test (the four
/// Table 2 families, whose full flows the e2e suite already exercises).
fn builtin_designs() -> Vec<(Generated, bool)> {
    vec![
        (
            rsir::designs::cnn::generate(&rsir::designs::cnn::CnnConfig { rows: 4, cols: 4 })
                .unwrap(),
            true,
        ),
        (
            rsir::designs::llama2::generate(&Default::default()).unwrap(),
            true,
        ),
        (rsir::designs::minimap2::generate().unwrap(), true),
        (
            rsir::designs::knn::generate(&Default::default()).unwrap(),
            true,
        ),
        (rsir::designs::catapult::generate().unwrap(), false),
        (
            rsir::designs::dynamatic::generate(rsir::designs::dynamatic::EXAMPLES[0]).unwrap(),
            false,
        ),
        (
            rsir::designs::intel_hls::generate(rsir::designs::intel_hls::CHSTONE[0]).unwrap(),
            false,
        ),
    ]
}

/// Every grouped module's indexed view must equal the reference graph.
fn assert_index_matches_reference(d: &Design, index: &mut DesignIndex) -> usize {
    let mut grouped = 0;
    for m in d.modules.values() {
        if !m.is_grouped() {
            continue;
        }
        grouped += 1;
        let (conn, interner) = index.conn(d, &m.name).unwrap();
        let view = conn.to_block_graph(interner);
        assert_eq!(
            view,
            reference_block_graph(m),
            "indexed view diverges from reference for module '{}'",
            m.name
        );
    }
    grouped
}

#[test]
fn indexed_view_matches_reference_for_all_builtin_designs() {
    let mut grouped_total = 0;
    for (g, run_analyze) in builtin_designs() {
        let mut d = g.design;
        // Pre-pass: fresh index over the imported design.
        let mut fresh = DesignIndex::for_design(&d);
        grouped_total += assert_index_matches_reference(&d, &mut fresh);

        if !run_analyze {
            continue;
        }
        // Post-pass: the pipeline's own (warm) index, after every cache
        // invalidation the real passes performed.
        let mut ctx = PassContext::new();
        ctx.drc_after_each = false;
        flow::analyze_structure(&mut d, &mut ctx).unwrap();
        grouped_total += assert_index_matches_reference(&d, &mut ctx.index);
    }
    assert!(grouped_total > 0, "no grouped modules were compared");
}

#[test]
fn analysis_pipeline_is_byte_identical_with_and_without_caching() {
    let make = || {
        rsir::designs::llama2::generate(&Default::default())
            .unwrap()
            .design
    };
    let run = |caching: bool| {
        let mut d = make();
        let mut ctx = PassContext::new();
        ctx.drc_after_each = false;
        ctx.index.set_caching(caching);
        flow::analyze_structure(&mut d, &mut ctx).unwrap();
        (design_to_json(&d).pretty(), ctx)
    };
    let (json_cached, ctx_cached) = run(true);
    let (json_uncached, ctx_uncached) = run(false);
    assert_eq!(json_cached, json_uncached, "IR JSON must not depend on caching");
    assert_eq!(ctx_cached.log, ctx_uncached.log);
    assert_eq!(ctx_cached.namemap.len(), ctx_uncached.namemap.len());
    // The cached run actually exercised the cache.
    let (hits, misses) = ctx_cached.index.cache_stats();
    assert!(hits > 0, "expected cache hits, got {hits}/{misses}");
    assert_eq!(ctx_uncached.index.cache_stats().0, 0);
}

#[test]
fn full_flow_through_index_is_byte_deterministic() {
    let dev = rsir::device::builtin::by_name("u280").unwrap();
    let cfg = flow::FlowConfig {
        sa_refine: false,
        ..Default::default()
    };
    let run = || {
        let mut d = rsir::designs::llama2::generate(&Default::default())
            .unwrap()
            .design;
        flow::run_hlps(&mut d, &dev, &cfg).unwrap();
        design_to_json(&d).pretty()
    };
    assert_eq!(run(), run(), "optimized IR JSON must be byte-identical");

    // Table 2 rendering of one row, byte-for-byte.
    let render = || {
        let row = report::run_row("CNN 4x4", "cnn:4x4", "u250", &cfg).unwrap();
        report::render_table2(&[row]).to_string()
    };
    assert_eq!(render(), render(), "Table 2 bytes must be identical");
}

//! Integration tests for the `rsir serve` daemon:
//!
//! * the tier-1 daemon-vs-one-shot differential gate (32 fuzzed designs
//!   through `testing::fuzz::run_daemon`, i.e. one live daemon, two
//!   concurrent connections, warm resubmits, mid-flight cancellation);
//! * protocol framing edge cases against a *live* daemon over raw socket
//!   writes (partial lines, malformed JSON, unknown types, oversized
//!   payloads, cancel-unknown-job, duplicate ids, deadline expiry);
//! * a seeded never-panic property: hundreds of mutated request lines
//!   must each produce a typed response (or nothing), never kill the
//!   server;
//! * warm-cache behaviour observable through `stats` (memoized resubmits,
//!   per-job wall times) and version skew data in `hello`.

use std::io::Write;
use std::thread;
use std::time::{Duration, Instant};

use rsir::designs::synthetic::SyntheticConfig;
use rsir::server::client::{run_batch_local, run_batch_remote};
use rsir::server::protocol::{LineEvent, LineReader, DEFAULT_MAX_LINE, PROTOCOL_VERSION, VERSION};
use rsir::server::{connect, scratch_socket, Bind, ServeConfig, Server, Stream};
use rsir::testing::fuzz;
use rsir::util::rng::Rng;

/// Boot a quiet daemon on a scratch unix socket. Returns its endpoint and
/// the join handle for the server thread (joined after `shutdown`).
fn boot(
    tag: &str,
    tweak: impl FnOnce(&mut ServeConfig),
) -> (Bind, thread::JoinHandle<anyhow::Result<()>>) {
    let mut cfg = ServeConfig::new(Bind::Unix(scratch_socket(tag)));
    cfg.workers = 2;
    cfg.quiet = true;
    tweak(&mut cfg);
    let server = Server::bind(cfg).unwrap();
    let endpoint = server.endpoint();
    (endpoint, thread::spawn(move || server.run()))
}

fn shutdown(endpoint: &Bind, handle: thread::JoinHandle<anyhow::Result<()>>) {
    let ack = run_batch_remote(
        endpoint,
        &[r#"{"id":"down","type":"shutdown"}"#.to_string()],
        Duration::from_secs(30),
    )
    .unwrap();
    assert!(ack[0].contains("shutting_down"), "{}", ack[0]);
    handle.join().unwrap().unwrap();
}

/// A raw client connection: byte-level writes (so tests control framing
/// exactly) and line-at-a-time reads through the same `LineReader` the
/// daemon uses.
struct Raw {
    stream: Stream,
    reader: LineReader<Stream>,
}

impl Raw {
    fn open(endpoint: &Bind) -> Raw {
        let stream = connect(endpoint).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        let reader = LineReader::new(stream.try_clone().unwrap(), DEFAULT_MAX_LINE);
        Raw { stream, reader }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
        self.stream.flush().unwrap();
    }

    /// Next response line, polling through idle reads until `deadline`.
    fn recv(&mut self, deadline: Duration) -> String {
        let end = Instant::now() + deadline;
        loop {
            match self.reader.poll_line().unwrap() {
                LineEvent::Line(l) => return l,
                LineEvent::Idle => {
                    assert!(Instant::now() < end, "timed out waiting for a response");
                }
                other => panic!("connection ended early: {other:?}"),
            }
        }
    }

    /// Send one request line (newline appended) and return the response.
    fn roundtrip(&mut self, line: &str) -> String {
        self.send(format!("{line}\n").as_bytes());
        self.recv(Duration::from_secs(120))
    }
}

/// The acceptance gate: 32 fuzzed designs, every daemon response byte
/// (including warm-cache resubmits and the post-cancellation resubmit)
/// identical to the one-shot `run_batch_local` lane. Replay any failure
/// with `rsir fuzz --daemon --seed 2026 --cases 32`.
#[test]
fn daemon_equivalence_over_32_fuzzed_designs() {
    let rep = fuzz::run_daemon(2026, 32, &SyntheticConfig::default());
    assert!(
        rep.is_clean(),
        "daemon-equivalence violations:\n{}\nminimal counterexample:\n{}",
        rep.violations.join("\n"),
        rep.minimal_json.as_deref().unwrap_or("(batch-only failure)")
    );
}

#[test]
fn framing_edge_cases_yield_typed_errors_and_the_connection_survives() {
    let (endpoint, handle) = boot("frame", |cfg| cfg.max_line = 512);
    let mut c = Raw::open(&endpoint);

    // Malformed JSON: typed bad-json error, null id (nothing to echo).
    let r = c.roundtrip("this is not json");
    assert!(r.starts_with(r#"{"id":null,"ok":false"#), "{r}");
    assert!(r.contains(r#""code":"bad-json""#), "{r}");

    // Valid JSON, wrong shape: bad-request.
    let r = c.roundtrip("[1,2,3]");
    assert!(r.contains(r#""code":"bad-request""#), "{r}");
    assert!(r.contains("must be a JSON object"), "{r}");

    // Unknown request type: the id still comes back.
    let r = c.roundtrip(r#"{"id":"u1","type":"wat"}"#);
    assert_eq!(
        r,
        r#"{"id":"u1","ok":false,"error":{"code":"unknown-type","message":"unknown request type 'wat'"}}"#
    );

    // Unknown envelope key: rejected rather than silently ignored.
    let r = c.roundtrip(r#"{"id":"u2","type":"hello","extra":1}"#);
    assert!(r.contains(r#""code":"bad-request""#), "{r}");
    assert!(r.contains("unknown envelope key 'extra'"), "{r}");

    // Oversized line (max_line = 512): one typed error, then the stream
    // recovers at the next newline and keeps serving.
    let huge = format!("{{\"id\":\"big\",\"type\":\"hello\",\"params\":{{\"x\":\"{}\"}}}}\n", "y".repeat(1024));
    c.send(huge.as_bytes());
    let r = c.recv(Duration::from_secs(10));
    assert_eq!(
        r,
        r#"{"id":null,"ok":false,"error":{"code":"oversized","message":"request line exceeds 512 bytes"}}"#
    );
    let r = c.roundtrip(r#"{"id":"after","type":"hello"}"#);
    assert!(r.contains(r#""id":"after","ok":true"#), "{r}");

    // Partial line split across writes (with a pause longer than the
    // server's read timeout): reassembled into one request.
    c.send(br#"{"id":"sp","ty"#);
    thread::sleep(Duration::from_millis(250));
    c.send(b"pe\":\"hello\"}\n");
    let r = c.recv(Duration::from_secs(10));
    assert!(r.starts_with(r#"{"id":"sp","ok":true"#), "{r}");

    // Cancel for a job this connection never submitted.
    let r = c.roundtrip(r#"{"id":"c1","type":"cancel","params":{"job":"nope"}}"#);
    assert_eq!(
        r,
        r#"{"id":"c1","ok":false,"error":{"code":"unknown-job","message":"no such job 'nope'"}}"#
    );

    // Job without a usable id: rejected up front (its response would be
    // unmatchable), same bytes as the one-shot lane.
    let r = c.roundtrip(r#"{"type":"pipeline","params":{"bench":"cnn:2x2"}}"#);
    assert_eq!(
        r,
        r#"{"id":null,"ok":false,"error":{"code":"bad-request","message":"job requests require a string or numeric id"}}"#
    );

    // Duplicate job id on one connection: first runs, second is rejected.
    let r = c.roundtrip(r#"{"id":"j1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#);
    assert!(r.starts_with(r#"{"id":"j1","ok":true"#), "{r}");
    let r = c.roundtrip(r#"{"id":"j1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#);
    assert_eq!(
        r,
        r#"{"id":"j1","ok":false,"error":{"code":"duplicate-job","message":"job id 'j1' already used on this connection"}}"#
    );

    // timeout_ms: 0 — the deadline is already past at the first
    // cancellation checkpoint, so the job dies with the typed error.
    let r = c.roundtrip(r#"{"id":"t0","type":"flow","params":{"bench":"cnn:2x2"},"timeout_ms":0}"#);
    assert_eq!(
        r,
        r#"{"id":"t0","ok":false,"error":{"code":"timeout","message":"job deadline exceeded"}}"#
    );

    shutdown(&endpoint, handle);
}

/// Every framing-edge-case response above must be byte-identical to the
/// one-shot lane's verdict on the same lines (the determinism contract
/// covers errors too). Raw-byte cases (oversized/partial) are framing
/// concerns with no one-shot analogue and are exercised above.
#[test]
fn error_responses_match_the_one_shot_lane() {
    let lines: Vec<String> = [
        "this is not json",
        "[1,2,3]",
        r#"{"id":"u1","type":"wat"}"#,
        r#"{"id":"u2","type":"hello","extra":1}"#,
        r#"{"id":"c1","type":"cancel","params":{"job":"nope"}}"#,
        r#"{"type":"pipeline","params":{"bench":"cnn:2x2"}}"#,
        r#"{"id":"j1","type":"pipeline","params":{"bench":"nosuchbench"}}"#,
        r#"{"id":"j2","type":"fuzz","params":{"cases":0}}"#,
        r#"{"id":"j3","type":"explore","params":{"bench":"cnn:2x2","limits":[2.0]}}"#,
        r#"{"id":"j4","type":"flow","params":{"bench":"cnn:2x2","bogus":1}}"#,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let (endpoint, handle) = boot("errs", |_| {});
    let remote = run_batch_remote(&endpoint, &lines, Duration::from_secs(60)).unwrap();
    let local = run_batch_local(&lines);
    assert_eq!(remote, local);
    // And they really are typed errors, not accidental successes.
    for (line, resp) in lines.iter().zip(&remote) {
        assert!(resp.contains(r#""ok":false"#), "{line} -> {resp}");
    }
    shutdown(&endpoint, handle);
}

/// Never-panic property: seeded byte-level mutations of a valid request
/// line (truncations, flips, span deletions — the same operators as the
/// Verilog frontend fuzz) are thrown at a live daemon. The server must
/// stay up and answer a fresh `hello` afterwards.
#[test]
fn mutated_request_lines_never_kill_the_daemon() {
    let (endpoint, handle) = boot("mutate", |cfg| cfg.max_line = 4096);
    let base = r#"{"id":"m","type":"hello","params":{}}"#.as_bytes().to_vec();
    let mut rng = Rng::new(2026);
    let mut c = Raw::open(&endpoint);
    for _ in 0..300 {
        let mut bytes = base.clone();
        match rng.below(4) {
            0 => {
                let cut = rng.below(bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                let at = rng.below(bytes.len());
                bytes[at] = 0x20 + rng.below(0x5f) as u8;
            }
            2 => {
                let at = rng.below(bytes.len());
                let len = (rng.below(8) + 1).min(bytes.len() - at);
                bytes.drain(at..at + len);
            }
            _ => {
                // pure noise line
                bytes = (0..rng.below(64)).map(|_| 0x20 + rng.below(0x5f) as u8).collect();
            }
        }
        bytes.push(b'\n');
        c.send(&bytes);
    }
    // Drain whatever typed responses the garbage produced, then prove the
    // daemon is still alive: a tagged hello must come back.
    c.send(b"{\"id\":\"alive\",\"type\":\"hello\"}\n");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        assert!(Instant::now() < deadline, "daemon stopped answering");
        let line = c.recv(Duration::from_secs(60));
        if line.contains(r#""id":"alive""#) {
            assert!(line.contains(r#""ok":true"#), "{line}");
            break;
        }
    }
    shutdown(&endpoint, handle);
}

/// Warm-cache behaviour through the protocol: an identical resubmit is a
/// result-memo hit (same bytes, different id), and `stats` reports cache
/// hits, queue state, and per-job wall times.
#[test]
fn stats_reports_cache_hits_and_wall_times() {
    let (endpoint, handle) = boot("stats", |_| {});
    let mut c = Raw::open(&endpoint);

    let hello = c.roundtrip(r#"{"id":"h","type":"hello"}"#);
    assert!(hello.contains(&format!(r#""version":"{VERSION}""#)), "{hello}");
    assert!(hello.contains(&format!(r#""protocol":{PROTOCOL_VERSION}"#)), "{hello}");

    let params = r#"{"bench":"cnn:3x2","device":"u250","sa_refine":false}"#;
    let cold = c.roundtrip(&format!(r#"{{"id":"f1","type":"flow","params":{params}}}"#));
    let warm = c.roundtrip(&format!(r#"{{"id":"f2","type":"flow","params":{params}}}"#));
    assert!(cold.starts_with(r#"{"id":"f1","ok":true"#), "{cold}");
    // Identical payload bytes after the id: the memoized result is the
    // same Json value, re-rendered.
    assert_eq!(
        cold.strip_prefix(r#"{"id":"f1","#).unwrap(),
        warm.strip_prefix(r#"{"id":"f2","#).unwrap()
    );

    let stats = c.roundtrip(r#"{"id":"s","type":"stats"}"#);
    for needle in [
        r#""queue_depth":"#,
        r#""running":"#,
        r#""enqueued":2"#,
        r#""completed":2"#,
        r#""results":{"hits":1,"misses":1"#,
        r#""recent_jobs":"#,
        r#""id":"f1","wall_ms":"#,
        r#""id":"f2","wall_ms":"#,
    ] {
        assert!(stats.contains(needle), "missing {needle} in {stats}");
    }

    // A third flow with a different util limit misses every whole-request
    // cache (new result key, new floorplan) but reuses per-stage work
    // through the stage memo: the baseline netlist, its placement, and
    // its STA terms are identical to f1's, so the flatten/placement
    // caches hit and the delta-STA lane takes over.
    let p3 = r#"{"bench":"cnn:3x2","device":"u250","sa_refine":false,"util":0.6}"#;
    let third = c.roundtrip(&format!(r#"{{"id":"f3","type":"flow","params":{p3}}}"#));
    assert!(third.starts_with(r#"{"id":"f3","ok":true"#), "{third}");
    let stats = c.roundtrip(r#"{"id":"s2","type":"stats"}"#);
    let parsed = rsir::util::json::Json::parse(&stats).unwrap();
    let caches = parsed
        .at("result")
        .and_then(|r| r.at("caches"))
        .expect("stats payload has a caches object")
        .clone();
    for name in [
        "module_chars",
        "flat_fragments",
        "flat_netlists",
        "placements",
        "floorplans",
        "sta_delta",
    ] {
        assert!(
            caches.at(name).is_some(),
            "missing per-stage cache '{name}' in {stats}"
        );
    }
    let hits = |name: &str| {
        caches
            .at(name)
            .and_then(|s| s.at("hits"))
            .and_then(|h| h.as_f64())
            .unwrap_or(-1.0)
    };
    assert!(hits("flat_netlists") >= 1.0, "no netlist reuse: {stats}");
    assert!(hits("placements") >= 1.0, "no placement reuse: {stats}");
    assert!(hits("sta_delta") >= 1.0, "delta STA never ran: {stats}");
    shutdown(&endpoint, handle);
}

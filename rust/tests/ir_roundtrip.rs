//! Integration: IR round-trip and pass-invariant properties over
//! randomized designs (DESIGN.md invariants 4–6).

use rsir::ir::builder::*;
use rsir::ir::core::*;
use rsir::ir::schema;
use rsir::ir::validate;
use rsir::passes::manager::{Pass, PassContext};
use rsir::util::json::Json;
use rsir::util::quickcheck::{forall, Gen};
use rsir::util::rng::Rng;

/// Random clean handshake-chain design generator for property tests.
struct ChainDesignGen;

impl Gen for ChainDesignGen {
    type Item = (u64, usize, u32);
    fn generate(&self, rng: &mut Rng) -> Self::Item {
        (rng.next_u64(), rng.range(2, 8), 8 << rng.below(4))
    }
    fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
        let mut v = Vec::new();
        if item.1 > 2 {
            v.push((item.0, item.1 - 1, item.2));
        }
        if item.2 > 8 {
            v.push((item.0, item.1, item.2 / 2));
        }
        v
    }
}

fn build_chain(seed: u64, n: usize, width: u32) -> Design {
    let mut rng = Rng::new(seed);
    let mut d = Design::new("Top");
    let mut top = GroupedBuilder::new("Top")
        .port("ap_clk", Dir::In, 1)
        .iface(Interface::Clock {
            port: "ap_clk".into(),
        });
    for i in 0..n {
        let m = LeafBuilder::verilog_stub(format!("M{i}"))
            .port("ap_clk", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .handshake("i", Dir::In, width)
            .handshake("o", Dir::Out, width)
            .resource(Resources::new(
                1000.0 + rng.below(50_000) as f64,
                500.0,
                2.0,
                8.0,
                0.0,
            ))
            .build();
        d.add(m);
    }
    for i in 0..n.saturating_sub(1) {
        top = top
            .wire(&format!("w{i}"), width)
            .wire(&format!("w{i}_vld"), 1)
            .wire(&format!("w{i}_rdy"), 1);
    }
    for i in 0..n {
        let mut inst = Instance::new(format!("m{i}"), format!("M{i}"));
        inst.connect("ap_clk", ConnExpr::id("ap_clk"));
        if i > 0 {
            inst.connect("i", ConnExpr::id(&format!("w{}", i - 1)));
            inst.connect("i_vld", ConnExpr::id(&format!("w{}_vld", i - 1)));
            inst.connect("i_rdy", ConnExpr::id(&format!("w{}_rdy", i - 1)));
        }
        if i + 1 < n {
            inst.connect("o", ConnExpr::id(&format!("w{i}")));
            inst.connect("o_vld", ConnExpr::id(&format!("w{i}_vld")));
            inst.connect("o_rdy", ConnExpr::id(&format!("w{i}_rdy")));
        }
        top = top.inst_full(inst);
    }
    d.add(top.build());
    d
}

#[test]
fn property_json_roundtrip_preserves_design() {
    forall(0xAB, 30, &ChainDesignGen, |&(seed, n, w)| {
        let d = build_chain(seed, n, w);
        let j = schema::design_to_json(&d);
        let text = j.pretty();
        let d2 = schema::design_from_json(&Json::parse(&text).unwrap()).unwrap();
        d == d2
    });
}

#[test]
fn property_export_reimport_preserves_leaf_sources() {
    forall(0xCD, 20, &ChainDesignGen, |&(seed, n, w)| {
        let d = build_chain(seed, n, w);
        let bundle = rsir::plugins::export(&d).unwrap();
        let leaves = bundle.file("design_leaves.v").unwrap();
        // Every leaf's embedded source appears verbatim.
        d.modules.values().all(|m| match &m.body {
            Body::Leaf { source, .. } => leaves.contains(source.as_str()),
            _ => true,
        })
    });
}

#[test]
fn property_group_then_flatten_preserves_edges() {
    forall(0xEF, 20, &ChainDesignGen, |&(seed, n, w)| {
        if n < 3 {
            return true;
        }
        let d = build_chain(seed, n, w);
        let edges_of = |d: &Design| {
            let g = rsir::ir::graph::BlockGraph::build(d.top_module());
            let mut v: Vec<u64> = g.instance_edges(&["ap_clk".into()]).iter().map(|e| e.2).collect();
            v.sort();
            v
        };
        let before = edges_of(&d);
        let mut d2 = d.clone();
        let mut ctx = PassContext::new();
        rsir::passes::group::group_instances(
            &mut d2,
            "Top",
            &["m0".into(), "m1".into()],
            "G01",
            &mut ctx,
        )
        .unwrap();
        validate::check(&d2).is_empty()
            && {
                rsir::passes::flatten::Flatten.run(&mut d2, &mut ctx).unwrap();
                validate::check(&d2).is_empty() && edges_of(&d2) == before
            }
    });
}

#[test]
fn property_pipeline_insert_preserves_drc_and_fmax_improves_or_holds() {
    forall(0x11, 15, &ChainDesignGen, |&(seed, n, w)| {
        let mut d = build_chain(seed, n, w);
        let mut ctx = PassContext::new();
        // Insert a relay station on every forward channel.
        for i in 0..n.saturating_sub(1) {
            rsir::passes::pipeline_insert::insert_relay_station(
                &mut d,
                "Top",
                &format!("m{i}"),
                "o",
                1,
                None,
                &mut ctx,
            )
            .unwrap();
        }
        validate::check(&d).is_empty()
    });
}

#[test]
fn yaml_dump_of_real_ir_contains_paper_fields() {
    let d = build_chain(7, 3, 32);
    let y = rsir::util::yamlish::to_yaml(&schema::design_to_json(&d));
    for f in ["module_name:", "module_ports:", "module_interfaces:", "iface_type: handshake"] {
        assert!(y.contains(f), "missing {f} in yaml dump");
    }
}

#[test]
fn namemap_traces_through_full_flow() {
    let dev = rsir::device::builtin::by_name("u280").unwrap();
    let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
    let mut d = g.design;
    let mut ctx = PassContext::new();
    // Match the flow's stage-1 contract: no interleaved DRC (mid-rebuild
    // states may be transiently inconsistent).
    ctx.drc_after_each = false;
    rsir::coordinator::flow::analyze_structure(&mut d, &mut ctx).unwrap();
    let _ = dev;
    // Flattened instance names trace back to hierarchical paths.
    assert!(!ctx.namemap.is_empty());
    let top = d.top_module();
    let traced: Vec<String> = top
        .instances()
        .iter()
        .map(|i| ctx.namemap.trace(&i.instance_name))
        .collect();
    assert!(traced.iter().any(|t| t.contains('/')), "{traced:?}");
}

//! Fault-injection integration tests: the only process allowed to arm
//! *production* fault sites (`testing::faults` is process-global, and
//! the lib test binary hosts live daemons that must stay uninjected —
//! its unit tests arm reserved `test.*` names only).
//!
//! Covers the tier-1 fault-resilience gate (64 fuzzed (design,
//! fault-plan) pairs through `testing::fuzz::run_faults`, with forced
//! coverage of all five fault categories), the scheduled 256-case lane
//! (`#[ignore]`, mirrored by CI's `rsir fuzz --faults` job), and the
//! targeted hardening properties: cancellation beating an injected
//! fault, the typed `internal-panic` envelope, `LineReader`'s
//! no-byte-loss contract, and the retrying client surviving a killed
//! connection.

use std::io::Cursor;
use std::sync::{Mutex, MutexGuard};
use std::thread;
use std::time::Duration;

use rsir::designs::synthetic::SyntheticConfig;
use rsir::server::client::{
    run_batch_local, run_batch_remote, run_batch_remote_with, RetryPolicy,
};
use rsir::server::protocol::{LineEvent, LineReader};
use rsir::server::{scratch_socket, Bind, ServeConfig, Server};
use rsir::testing::faults::{self, FaultAction, FaultArm, FaultPlan};
use rsir::testing::fuzz;

/// The fault plane is process-global and `faults::arm` only serializes
/// *armers* — a test that booted an unarmed daemon would still see
/// another test's injections. So every test in this binary serializes
/// behind one lock for its whole body, daemons included.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn boot(
    tag: &str,
    tweak: impl FnOnce(&mut ServeConfig),
) -> (Bind, thread::JoinHandle<anyhow::Result<()>>) {
    let mut cfg = ServeConfig::new(Bind::Unix(scratch_socket(tag)));
    cfg.workers = 2;
    cfg.quiet = true;
    tweak(&mut cfg);
    let server = Server::bind(cfg).unwrap();
    let endpoint = server.endpoint();
    (endpoint, thread::spawn(move || server.run()))
}

fn shutdown(endpoint: &Bind, handle: thread::JoinHandle<anyhow::Result<()>>) {
    let ack = run_batch_remote(
        endpoint,
        &[r#"{"id":"down","type":"shutdown"}"#.to_string()],
        Duration::from_secs(30),
    )
    .unwrap();
    assert!(ack[0].contains("shutting_down"), "{}", ack[0]);
    handle.join().unwrap().unwrap();
}

fn batch(lines: &[&str]) -> Vec<String> {
    lines.iter().map(|s| s.to_string()).collect()
}

/// The acceptance gate: 64 fuzzed (design, fault-plan) pairs, the first
/// five arming one site per fault category (server IO, queue admission,
/// pool-job panic, stage-memo corruption, flow stage). Every request
/// must terminate with a typed error or bytes identical to the
/// fault-free one-shot lane, and every daemon must survive to an orderly
/// shutdown. Replay failures with `rsir fuzz --faults --seed 2026
/// --cases 64`.
#[test]
fn fault_resilience_over_64_design_fault_pairs() {
    let _s = serial();
    let rep = fuzz::run_faults(2026, 64, &SyntheticConfig::default());
    assert!(
        rep.is_clean(),
        "fault-resilience violations:\n{}\nminimal pair:\n{}",
        rep.violations.join("\n"),
        rep.minimal_json.as_deref().unwrap_or("(none)")
    );
    for site in [
        "server.io.read",
        "server.queue.push",
        "pool.job",
        "memo.place.insert",
        "flow.stage.floorplan",
    ] {
        assert!(
            rep.covered.contains(site),
            "coverage schedule must arm {site}; covered: {:?}",
            rep.covered
        );
    }
}

/// The scheduled deep lane (CI runs the equivalent `rsir fuzz --faults
/// --cases 256` nightly and uploads the counterexample artifact).
#[test]
#[ignore = "scheduled lane: 256 cases is too slow for tier-1"]
fn scheduled_fault_fuzz_256_cases() {
    let _s = serial();
    let rep = fuzz::run_faults(1, 256, &SyntheticConfig::default());
    if let Some(json) = &rep.minimal_json {
        std::fs::write("../fuzz_faults_counterexample.json", json).unwrap();
    }
    assert!(
        rep.is_clean(),
        "fault-resilience violations:\n{}",
        rep.violations.join("\n")
    );
}

/// A cancel landing inside an injected delay must win: the client gets
/// its typed `canceled` reply, never the injected stage error — and the
/// canceled job must not have poisoned any memo (a fresh resubmit still
/// byte-matches the one-shot lane).
#[test]
fn cancel_during_injected_delay_yields_canceled_not_injected() {
    let _s = serial();
    let resubmit = r#"{"id":"j2","type":"flow","params":{"bench":"cnn:2x2","sa_refine":false,"seed":7}}"#;
    // Fault-free expectation for the resubmit, before anything is armed.
    let expect = run_batch_local(&batch(&[resubmit]));

    let (endpoint, handle) = boot("cancel-delay", |_| {});
    {
        // Delay at the first stage checkpoint opens a 120ms window for
        // the cancel; the Error arm at the next checkpoint would fire if
        // cancellation did NOT win — the assertion below proves it never
        // reaches the client.
        let _g = faults::arm(&FaultPlan {
            arms: vec![
                FaultArm::new("flow.stage.start", 1, FaultAction::Delay),
                FaultArm::new("flow.stage.analysis", 1, FaultAction::Error),
            ],
        });
        let lines = batch(&[
            r#"{"id":"j1","type":"flow","params":{"bench":"cnn:2x2","sa_refine":false,"seed":7}}"#,
            r#"{"id":"c1","type":"cancel","params":{"job":"j1"}}"#,
        ]);
        let got = run_batch_remote(&endpoint, &lines, Duration::from_secs(120)).unwrap();
        assert!(
            got[0].contains(r#""code":"canceled""#),
            "canceled job response: {}",
            got[0]
        );
        assert!(
            !got[0].contains("injected fault"),
            "injected error leaked past cancellation: {}",
            got[0]
        );
        assert!(got[1].contains(r#""canceled":"j1""#), "{}", got[1]);
    }
    // Disarmed again: the resubmit recomputes cold and must match the
    // fault-free one-shot lane byte for byte.
    let got = run_batch_remote(&endpoint, &batch(&[resubmit]), Duration::from_secs(120)).unwrap();
    assert_eq!(got, expect, "canceled job poisoned a memo");
    shutdown(&endpoint, handle);
}

/// An injected panic in a job body becomes the typed `internal-panic`
/// envelope — identical bytes from the daemon and the one-shot lane —
/// the daemon keeps serving, and the next job is unaffected.
#[test]
fn injected_job_panic_yields_typed_envelope_and_daemon_survives() {
    let _s = serial();
    let j1 = r#"{"id":"j1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#;
    let j2 = r#"{"id":"j2","type":"flow","params":{"bench":"cnn:2x2","sa_refine":false,"seed":7}}"#;
    let expect_j2 = run_batch_local(&batch(&[j2]));

    // One worker: queue order decides which job eats the panic.
    let (endpoint, handle) = boot("panic-env", |cfg| cfg.workers = 1);
    let daemon_j1;
    {
        let _g = faults::arm(&FaultPlan::one("pool.job", 1, FaultAction::Panic));
        let got = run_batch_remote(&endpoint, &batch(&[j1, j2]), Duration::from_secs(120)).unwrap();
        assert!(
            got[0].contains(r#""code":"internal-panic""#) && got[0].contains("job panicked"),
            "panicking job response: {}",
            got[0]
        );
        assert_eq!(got[1], expect_j2[0], "job after the panic diverged");
        daemon_j1 = got[0].clone();
    }
    // The one-shot lane shares the panic barrier: same plan, same line,
    // byte-identical envelope.
    {
        let _g = faults::arm(&FaultPlan::one("pool.job", 1, FaultAction::Panic));
        let local = run_batch_local(&batch(&[j1]));
        assert_eq!(local[0], daemon_j1, "panic envelope differs across lanes");
    }
    shutdown(&endpoint, handle);
}

/// `LineReader` under injected faults: short reads, a transport error
/// and a delay — in any interleaving it must never panic and never lose
/// a byte that already arrived (the injected error returns *before* the
/// read touches the buffer).
#[test]
fn line_reader_never_loses_bytes_under_injected_faults() {
    let _s = serial();
    let _g = faults::arm(&FaultPlan {
        arms: vec![
            FaultArm::new("test.io.lr", 1, FaultAction::ShortIo),
            FaultArm::new("test.io.lr", 2, FaultAction::Error),
            FaultArm::new("test.io.lr", 3, FaultAction::Delay),
        ],
    });
    let mut r = LineReader::with_site(Cursor::new(b"hello\nworld\n".to_vec()), 64, "test.io.lr");
    let mut lines = Vec::new();
    let mut errors = 0;
    loop {
        match r.poll_line() {
            Ok(LineEvent::Line(l)) => lines.push(l),
            Ok(LineEvent::Eof) => break,
            Ok(LineEvent::Idle) | Ok(LineEvent::Oversized) => {}
            Err(e) => {
                assert_eq!(e.to_string(), "injected fault at test.io.lr");
                errors += 1;
                assert!(errors < 10, "error did not clear");
            }
        }
    }
    // The short read delivered one byte, the error interrupted mid-line,
    // the delay stalled a read — and every byte still framed correctly.
    assert_eq!(lines, vec!["hello".to_string(), "world".to_string()]);
    assert_eq!(errors, 1, "exactly one transport error was injected");
    assert!(faults::fired_log().len() == 3, "{:?}", faults::fired_log());
}

/// The retrying client survives a connection the fault plane kills
/// mid-handshake: reconnect, resubmit, and return bytes identical to
/// the one-shot lane. A no-retry policy on the same fault fails — the
/// retry really is what saves the batch.
#[test]
fn retrying_client_survives_injected_connection_death() {
    let _s = serial();
    let job = r#"{"id":"p1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#;
    let expect = run_batch_local(&batch(&[job]));

    let (endpoint, handle) = boot("retry", |_| {});
    {
        // Hit 1 of server.io.read is the daemon's very first read on the
        // first connection: it dies before even the hello is answered.
        let _g = faults::arm(&FaultPlan::one("server.io.read", 1, FaultAction::Error));
        let got = run_batch_remote(&endpoint, &batch(&[job]), Duration::from_secs(120)).unwrap();
        assert_eq!(got, expect);
    }
    {
        let _g = faults::arm(&FaultPlan::one("server.io.read", 1, FaultAction::Error));
        let err = run_batch_remote_with(
            &endpoint,
            &batch(&[job]),
            Duration::from_secs(30),
            &RetryPolicy::none(),
        );
        assert!(err.is_err(), "single-attempt client should see the dead connection");
    }
    shutdown(&endpoint, handle);
}

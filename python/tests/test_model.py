"""L2 model: on-device argmin reduction + AOT lowering shape checks."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from tests.test_kernel import make_inputs


def test_score_returns_argmin():
    rng = np.random.default_rng(7)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 64, 16, 8)
    costs, best_idx, best_cost = model.score(a, c, d, r, caps, lam)
    costs = np.asarray(costs)
    assert costs.shape == (64,)
    assert int(best_idx[0]) == int(np.argmin(costs))
    np.testing.assert_allclose(best_cost[0], costs.min(), rtol=1e-6)


def test_score_matches_score_ref():
    rng = np.random.default_rng(8)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 64, 12, 6)
    got, gi, gc = model.score(a, c, d, r, caps, lam)
    want, wi, wc = model.score_ref(a, c, d, r, caps, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-2)
    assert int(gi[0]) == int(wi[0])


def test_lowering_produces_hlo_text():
    lowered = aot.lower_bucket(64, 32, 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # 3 outputs in a tuple: costs, best_idx, best_cost
    assert "ROOT" in text


def test_buckets_cover_builtin_devices():
    # S=8 covers every built-in board (max 8 slots); M up to 128 covers
    # coarsened problems (max_units default 24, generous headroom).
    assert all(s == 8 for _, _, s in aot.BUCKETS)
    assert max(m for _, m, _ in aot.BUCKETS) >= 128


def test_aot_writes_artifacts(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        # Patch buckets to one small one to keep the test fast.
        orig = aot.BUCKETS
        aot.BUCKETS = [(32, 16, 8)]
        aot.main()
        aot.BUCKETS = orig
    finally:
        sys.argv = argv
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["kernel"] == "floorplan_cost"
    f = tmp_path / man["buckets"][0]["file"]
    assert f.exists()
    assert "HloModule" in f.read_text()[:200]

"""Pallas kernel vs pure-jnp oracle - the core correctness signal,
including a hypothesis sweep over shapes and random inputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.floorplan_cost import floorplan_cost, vmem_bytes
from compile.kernels.ref import cost_scalar_ref, floorplan_cost_ref


def make_inputs(rng, b, m, s, k=5, overflow=False):
    """Random problem instance with realistic magnitudes."""
    # symmetric connectivity with zero diagonal
    c = rng.integers(0, 4, size=(m, m)).astype(np.float32) * 32.0
    c = np.triu(c, 1)
    c = c + c.T
    d = rng.uniform(0.0, 10.0, size=(s, s)).astype(np.float32)
    d = (d + d.T) / 2.0
    np.fill_diagonal(d, 0.0)
    r = rng.uniform(0.0, 5000.0, size=(m, k)).astype(np.float32)
    cap_scale = 0.5 if overflow else 50.0
    caps = (rng.uniform(0.5, 1.0, size=(s, k)) * m * 5000.0 * cap_scale / s).astype(
        np.float32
    )
    assign = rng.integers(0, s, size=(b, m))
    a = np.zeros((b, m, s), dtype=np.float32)
    for bi in range(b):
        a[bi, np.arange(m), assign[bi]] = 1.0
    lam = np.array([1e-4], dtype=np.float32)
    return a, c, d, r, caps, lam, assign


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 64, 16, 8)
    got = floorplan_cost(a, c, d, r, caps, lam, block_b=32)
    want = floorplan_cost_ref(a, c, d, r, caps, lam)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_kernel_matches_ref_with_overflow():
    rng = np.random.default_rng(1)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 64, 24, 8, overflow=True)
    got = floorplan_cost(a, c, d, r, caps, lam, block_b=64)
    want = floorplan_cost_ref(a, c, d, r, caps, lam)
    assert np.all(np.asarray(want) > 0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_matmul_identity_vs_scalar_formula():
    """The (C@A)*(A@D) identity equals the direct double loop."""
    rng = np.random.default_rng(2)
    a, c, d, r, caps, lam, assign = make_inputs(rng, 4, 10, 6)
    batched = np.asarray(floorplan_cost_ref(a, c, d, r, caps, lam))
    for bi in range(4):
        scalar = float(cost_scalar_ref(assign[bi], c, d, r, caps, lam))
        np.testing.assert_allclose(batched[bi], scalar, rtol=1e-5, atol=1e-2)


def test_grid_tiling_invariance():
    """Different block_b values must give identical results."""
    rng = np.random.default_rng(3)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 128, 16, 8)
    r1 = np.asarray(floorplan_cost(a, c, d, r, caps, lam, block_b=32))
    r2 = np.asarray(floorplan_cost(a, c, d, r, caps, lam, block_b=128))
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_batch_not_divisible_raises():
    rng = np.random.default_rng(4)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 65, 8, 8)
    with pytest.raises(ValueError):
        floorplan_cost(a, c, d, r, caps, lam, block_b=64)


def test_padding_neutrality():
    """Padded units (zero connectivity/resources, slot-0 one-hot) must
    not change the cost - the Rust evaluator relies on this."""
    rng = np.random.default_rng(5)
    a, c, d, r, caps, lam, _ = make_inputs(rng, 32, 12, 8)
    base = np.asarray(floorplan_cost(a, c, d, r, caps, lam, block_b=32))
    m_pad = 16
    a2 = np.zeros((32, m_pad, 8), dtype=np.float32)
    a2[:, :12] = a
    a2[:, 12:, 0] = 1.0  # padded units parked in slot 0
    c2 = np.zeros((m_pad, m_pad), dtype=np.float32)
    c2[:12, :12] = c
    r2 = np.zeros((m_pad, 5), dtype=np.float32)
    r2[:12] = r
    padded = np.asarray(floorplan_cost(a2, c2, d, r2, caps, lam, block_b=32))
    np.testing.assert_allclose(base, padded, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(1, 3),
    m=st.integers(2, 24),
    s=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
    overflow=st.booleans(),
)
def test_kernel_matches_ref_hypothesis(b_tiles, m, s, seed, overflow):
    """Hypothesis sweep: shapes x random inputs x overflow regimes."""
    rng = np.random.default_rng(seed)
    b = 16 * b_tiles
    a, c, d, r, caps, lam, _ = make_inputs(rng, b, m, s, overflow=overflow)
    got = np.asarray(floorplan_cost(a, c, d, r, caps, lam, block_b=16))
    want = np.asarray(floorplan_cost_ref(a, c, d, r, caps, lam))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=0.5)


def test_vmem_budget():
    """Worst-case bucket stays within a 16 MiB VMEM budget (SPerf)."""
    assert vmem_bytes(64, 128, 8) < 16 * 2**20

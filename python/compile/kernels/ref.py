"""Pure-jnp oracle for the floorplan-cost kernel.

This is the CORE correctness reference: the Pallas kernel
(`floorplan_cost.py`), this module, and the Rust CPU oracle
(`rust/src/floorplan/cost.rs`) all implement the identical contract:

    inputs  A    [B, M, S]  one-hot assignment batch (f32)
            C    [M, M]     symmetric connectivity, zero diagonal
            D    [S, S]     slot distance (manhattan + die_w * crossings)
            R    [M, K]     unit resources, K = 5
            caps [S, K]     slot capacity * util_limit
            lam  [1]        overflow penalty weight
    output  cost [B] = 0.5 * sum((C@A) * (A@D), axis=(1,2))
                       + lam * sum(relu(A^T R - caps)^2, axis=(1,2))

The wirelength identity: sum_ij C[i,j] * (A D A^T)[i,j]
                       = sum((C@A) * (A@D)) elementwise.
"""

import jax.numpy as jnp

NUM_KINDS = 5


def floorplan_cost_ref(a, c, d, r, caps, lam):
    """Reference batched floorplan cost.

    Args:
      a:    f32[B, M, S] one-hot assignments.
      c:    f32[M, M] connectivity.
      d:    f32[S, S] slot distances.
      r:    f32[M, K] resources.
      caps: f32[S, K] capacities (already scaled by the util limit).
      lam:  f32[1] penalty weight.

    Returns:
      f32[B] per-candidate cost.
    """
    ca = jnp.einsum("ij,bjs->bis", c, a)
    ad = jnp.einsum("bms,st->bmt", a, d)
    wirelength = 0.5 * jnp.sum(ca * ad, axis=(1, 2))
    usage = jnp.einsum("bms,mk->bsk", a, r)
    over = jnp.maximum(usage - caps[None, :, :], 0.0)
    penalty = jnp.sum(over * over, axis=(1, 2))
    return wirelength + lam[0] * penalty


def cost_scalar_ref(assignment, c, d, r, caps, lam):
    """Direct (non-matmul) scalar formula for one candidate - used to
    validate the matmul identity itself."""
    m = c.shape[0]
    wl = 0.0
    for i in range(m):
        for j in range(i + 1, m):
            if c[i, j] != 0:
                wl += c[i, j] * d[assignment[i], assignment[j]]
    s, k = caps.shape
    usage = jnp.zeros((s, k))
    for i, slot in enumerate(assignment):
        usage = usage.at[slot].add(r[i])
    over = jnp.maximum(usage - caps, 0.0)
    return wl + lam[0] * jnp.sum(over * over)

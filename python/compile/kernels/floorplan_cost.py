"""L1 Pallas kernel: batched floorplan-candidate cost evaluation.

The compute hot-spot of RapidStream IR's floorplan exploration: the
simulated-annealing explorer proposes hundreds of candidate module→slot
assignments per step and needs them all scored. Per candidate the score
is two MXU matmul chains:

    wirelength = 0.5 * sum((C @ A) * (A @ D))      # C: M*M, A: M*S, D: S*S
    usage      = A^T @ R                           # S*K resource histogram
    cost       = wirelength + lam * sum(relu(usage - caps)^2)

TPU mapping (DESIGN.md "Hardware adaptation"): the grid walks the batch
dimension; each grid step holds one (BT, M, S) tile of assignments plus
the shared C/D/R/caps operands in VMEM. The shared operands use constant
index maps, so Mosaic keeps them resident across grid steps while the
assignment tiles stream HBM->VMEM (double-buffered by the pipeline).
`interpret=True` is REQUIRED on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_KINDS = 5
# Batch-tile: 64 candidates per grid step keeps worst-case VMEM (M=128,
# S=8) around 2 MiB while still feeding the MXU wide batched matmuls.
DEFAULT_BLOCK_B = 64


def _kernel(a_ref, c_ref, d_ref, r_ref, caps_ref, lam_ref, o_ref):
    a = a_ref[...]          # (BT, M, S)
    c = c_ref[...]          # (M, M)
    d = d_ref[...]          # (S, S)
    r = r_ref[...]          # (M, K)
    caps = caps_ref[...]    # (S, K)
    lam = lam_ref[0]

    # (M,M) x (BT,M,S) -> (BT,M,S): one batched MXU contraction.
    ca = jax.lax.dot_general(
        c, a, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (M, BT, S)
    ca = jnp.transpose(ca, (1, 0, 2))
    # (BT,M,S) x (S,S) -> (BT,M,S)
    ad = jax.lax.dot_general(
        a, d, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    wirelength = 0.5 * jnp.sum(ca * ad, axis=(1, 2))

    # usage[b,s,k] = sum_m a[b,m,s] * r[m,k]
    usage = jax.lax.dot_general(
        a, r, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BT, S, K)
    over = jnp.maximum(usage - caps[None, :, :], 0.0)
    penalty = jnp.sum(over * over, axis=(1, 2))

    o_ref[...] = wirelength + lam * penalty


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def floorplan_cost(a, c, d, r, caps, lam, *, block_b=DEFAULT_BLOCK_B, interpret=True):
    """Batched floorplan cost via a Pallas kernel.

    Shapes: a f32[B,M,S], c f32[M,M], d f32[S,S], r f32[M,K],
    caps f32[S,K], lam f32[1] -> f32[B]. B must divide by block_b.
    """
    b, m, s = a.shape
    k = r.shape[1]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((s, s), lambda i: (0, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((s, k), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(a, c, d, r, caps, lam)


def vmem_bytes(block_b, m, s, k=NUM_KINDS):
    """Estimated VMEM footprint of one grid step (f32), for the §Perf
    roofline discussion in DESIGN.md/EXPERIMENTS.md."""
    tile_a = block_b * m * s
    shared = m * m + s * s + m * k + s * k + 1
    scratch = 2 * block_b * m * s + block_b * s * k + block_b
    return 4 * (tile_a + shared + scratch)

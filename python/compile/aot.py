"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT `lowered.compile()`/`.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

One artifact per (B, M, S) shape bucket; the Rust evaluator pads the
problem into the nearest bucket (padding is cost-neutral by
construction: zero connectivity rows, zero resources, slot-0 one-hot).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (batch, units, slots): S=8 covers every built-in device (6-slot boards
# pad to 8); M buckets cover CNN-13x12-scale problems after coarsening.
BUCKETS = [
    (256, 32, 8),
    (256, 64, 8),
    (256, 128, 8),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(b, m, s):
    fn = lambda *args: model.score(*args, interpret=True)
    return jax.jit(fn).lower(*model.example_args(b, m, s))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"kernel": "floorplan_cost", "buckets": []}
    for b, m, s in BUCKETS:
        text = to_hlo_text(lower_bucket(b, m, s))
        name = f"floorplan_cost_b{b}_m{m}_s{s}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append(
            {"file": name, "batch": b, "units": m, "slots": s, "kinds": 5}
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()

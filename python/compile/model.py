"""L2 JAX model: the floorplan scoring computation graph.

Wraps the L1 Pallas kernel (`kernels.floorplan_cost`) with the reduction
the coordinator wants on-device: per-candidate costs plus the batch
argmin, so the PJRT round trip returns both the full score vector (for
per-chain Metropolis updates) and the global winner without a second
device call.

Build-time only: `aot.py` lowers `score` to HLO text once per shape
bucket; the Rust runtime executes the artifacts. Python is never on the
request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.floorplan_cost import floorplan_cost
from compile.kernels.ref import floorplan_cost_ref


def score(a, c, d, r, caps, lam, *, interpret=True):
    """Full L2 graph: kernel costs + on-device argmin.

    Returns (costs f32[B], best_idx i32[1], best_cost f32[1]).
    """
    block_b = min(64, a.shape[0])
    costs = floorplan_cost(a, c, d, r, caps, lam, block_b=block_b, interpret=interpret)
    best_idx = jnp.argmin(costs).astype(jnp.int32)
    best_cost = costs[best_idx]
    return costs, best_idx[None], best_cost[None]


def score_ref(a, c, d, r, caps, lam):
    """Same graph over the pure-jnp oracle (shape/semantics check)."""
    costs = floorplan_cost_ref(a, c, d, r, caps, lam)
    best_idx = jnp.argmin(costs).astype(jnp.int32)
    return costs, best_idx[None], costs[best_idx][None]


def example_args(b, m, s, k=5):
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((b, m, s), f32),
        jax.ShapeDtypeStruct((m, m), f32),
        jax.ShapeDtypeStruct((s, s), f32),
        jax.ShapeDtypeStruct((m, k), f32),
        jax.ShapeDtypeStruct((s, k), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )
